//! CELLAR-style constant-size SDDE (the paper's `MPIX_Alltoall_crs`
//! motivation, §I/§III): a cell-based AMR mesh is re-partitioned every few
//! steps; after each remesh, every rank knows which ranks it must send
//! cell data to (and how many cells), but not what it will receive. A
//! constant-size SDDE exchanges the cell *counts* so receive buffers can
//! be allocated before the bulk exchange.
//!
//! We simulate a drifting refinement front: the neighbor set changes each
//! remesh, and we compare all five algorithms (including RMA, which only
//! exists for the constant-size variant) across several remesh rounds.
//!
//! Run: `cargo run --release --example amr_halo`

use std::rc::Rc;

use sdde::prelude::*;
use sdde::util::{fmt, Rng};

/// Neighbor sets for one remesh round: each rank sends cell counts to a
/// locality-biased set of ranks that drifts over rounds.
fn remesh_pattern(n: usize, round: u64, seed: u64) -> Vec<CrsArgs> {
    (0..n)
        .map(|p| {
            let mut rng = Rng::stream(seed ^ (round * 0x9E37), p as u64);
            let deg = 3 + rng.usize_below(6);
            let mut dest = std::collections::BTreeSet::new();
            while dest.len() < deg {
                // mostly near neighbors, occasionally a far rank (load
                // balancing migration)
                let d = if rng.chance(0.8) {
                    (p as i64 + rng.range(-6, 7)).rem_euclid(n as i64) as usize
                } else {
                    rng.usize_below(n)
                };
                if d != p {
                    dest.insert(d);
                }
            }
            let dest: Vec<usize> = dest.into_iter().collect();
            let sendvals = dest
                .iter()
                .map(|_| 64 + rng.below(1024)) // cells to ship
                .collect();
            CrsArgs {
                dest,
                sendcount: 1,
                sendvals,
            }
        })
        .collect()
}

fn main() {
    let topo = Topology::quartz(4, 16);
    let n = topo.nranks();
    let rounds = 5u64;
    println!(
        "AMR remesh notification: {} ranks ({} nodes x {} ppn), {} remesh rounds",
        n, topo.nodes, topo.ppn, rounds
    );

    for algo in SddeAlgorithm::ALL {
        let mut total = 0u64;
        let mut internode = 0u64;
        for round in 0..rounds {
            let pats = Rc::new(remesh_pattern(n, round, 7));
            let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
            let out = world.run(move |c| {
                let pats = pats.clone();
                async move {
                    let mx = MpixComm::new(c.clone(), RegionKind::Node);
                    let info = MpixInfo::with_algorithm(algo);
                    let res = alltoall_crs(&mx, &info, &pats[c.rank()]).await.unwrap();
                    // sanity: counts are plausible cell counts
                    assert!(res.recvvals.iter().all(|&v| (64..1088).contains(&v)));
                    res.recv_nnz()
                }
            });
            total += out.end_time;
            internode = internode.max(out.counters.max_internode_per_rank());
        }
        println!(
            "  {:<18} total over {rounds} remeshes: {:>10}  (max inter-node msgs/rank {})",
            algo.name(),
            fmt::ns(total),
            internode
        );
    }
}
