//! Pattern explorer: the paper's §II motivation, quantified.
//!
//! For each of the four SuiteSparse analogs, show how the communication
//! pattern evolves with scale — per-rank neighbor counts (the SDDE's
//! `send_nnz`), message sizes, and the standard vs aggregated inter-node
//! message counts (the red dots of Figs. 5–8). This explains *why* each
//! matrix lands where it does in the figures: dielFilterV2clx barely
//! benefits from aggregation while cage14 is transformed by it.
//!
//! Run: `cargo run --release --example pattern_explorer [-- --div 16]`
//!
//! With `--trace out.json`, additionally run one fully-traced SDDE on the
//! first matrix and smallest topology and export a Chrome-trace JSON of it
//! (the dynamic counterpart of the static pattern statistics).

use std::path::PathBuf;
use std::rc::Rc;

use sdde::bench::figures::{run_once_traced, Variant};
use sdde::mpix::{IntraAlgo, SddeAlgorithm};
use sdde::simnet::{MpiFlavor, RegionKind, Topology};
use sdde::sparse::{MatrixPreset, Partition, SpmvPattern};
use sdde::trace::write_chrome_trace;
use sdde::util::Args;

fn main() {
    let args = Args::from_env();
    let div = args.get_parsed("div", 16usize);
    let ppn = args.get_parsed("ppn", 8usize);
    let node_counts: Vec<usize> = args
        .get_list("nodes")
        .map(|v| v.iter().map(|s| s.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![2, 4, 8, 16]);
    let trace_out: Option<PathBuf> = args.get("trace").map(PathBuf::from);

    println!("matrix analogs scaled by 1/{div}, {ppn} ranks/node\n");
    for preset in MatrixPreset::paper_set() {
        let preset = if div > 1 { preset.scaled(div) } else { preset };
        println!(
            "== {} (n={}, ~{} nnz) ==",
            preset.name,
            preset.n,
            preset.approx_nnz()
        );
        println!(
            "{:>6} {:>7} {:>12} {:>12} {:>14} {:>16} {:>12}",
            "nodes", "ranks", "mean nbrs", "max nbrs", "mean msg len", "internode (std)", "(aggregated)"
        );
        for &nodes in &node_counts {
            let topo = Topology::quartz(nodes, ppn);
            let nranks = topo.nranks();
            let part = Partition::new(preset.n, nranks);
            let pats: Vec<SpmvPattern> = (0..nranks)
                .map(|r| SpmvPattern::build(&preset, part, r, 2023))
                .collect();
            let nbrs: Vec<usize> = pats.iter().map(|p| p.recv_nnz()).collect();
            let sizes: Vec<usize> = pats.iter().map(|p| p.recv_size()).collect();
            let mean_nbrs = nbrs.iter().sum::<usize>() as f64 / nranks as f64;
            let max_nbrs = *nbrs.iter().max().unwrap();
            let mean_len = sizes.iter().sum::<usize>() as f64
                / nbrs.iter().sum::<usize>().max(1) as f64;
            // standard inter-node messages = neighbors on other nodes;
            // aggregated = distinct destination nodes (bounded by nodes-1).
            let mut std_max = 0usize;
            let mut agg_max = 0usize;
            for (r, p) in pats.iter().enumerate() {
                let my_node = topo.region_of(r, RegionKind::Node);
                let internode = p
                    .needed
                    .iter()
                    .filter(|(o, _)| topo.region_of(*o, RegionKind::Node) != my_node)
                    .count();
                let nodes_touched = p
                    .needed
                    .iter()
                    .map(|(o, _)| topo.region_of(*o, RegionKind::Node))
                    .filter(|&nd| nd != my_node)
                    .collect::<std::collections::BTreeSet<_>>()
                    .len();
                std_max = std_max.max(internode);
                agg_max = agg_max.max(nodes_touched);
            }
            println!(
                "{nodes:>6} {nranks:>7} {mean_nbrs:>12.1} {max_nbrs:>12} {mean_len:>14.1} {std_max:>16} {agg_max:>12}"
            );
        }
        println!();
    }
    println!("(aggregated counts are bounded by nodes-1 — the mechanism behind the paper's 20x)");

    // Optional: one traced SDDE on the first matrix / smallest topology,
    // exported as Chrome-trace JSON for chrome://tracing or Perfetto.
    if let Some(path) = trace_out {
        let preset = MatrixPreset::paper_set().remove(0);
        let preset = if div > 1 { preset.scaled(div) } else { preset };
        let nodes = node_counts.first().copied().unwrap_or(2);
        let topo = Topology::quartz(nodes, ppn);
        let part = Partition::new(preset.n, topo.nranks());
        let pats: Rc<Vec<SpmvPattern>> = Rc::new(
            (0..topo.nranks())
                .map(|r| SpmvPattern::build(&preset, part, r, 2023))
                .collect(),
        );
        let (t, trace) = run_once_traced(
            topo,
            MpiFlavor::Mvapich2,
            SddeAlgorithm::LocalityNonBlocking,
            RegionKind::Node,
            IntraAlgo::Personalized,
            Variant::Variable,
            pats,
        );
        write_chrome_trace(&path, &trace.events).expect("writing trace");
        println!(
            "\ntraced {} on {nodes} nodes x {ppn} ppn (loc-nonblocking, {} ns): \
             wrote {} ({} events)",
            preset.name,
            t,
            path.display(),
            trace.events.len()
        );
    }
}
