//! Quickstart: the smallest possible SDDE.
//!
//! Eight simulated ranks on two nodes each know which ranks they must send
//! a few integers to — but not who will send to *them*. One
//! `MPIX_Alltoallv_crs` call discovers the receive side. We run it with
//! every algorithm and print what each rank learned plus the virtual time.
//!
//! Run: `cargo run --release --example quickstart`

use std::rc::Rc;

use sdde::prelude::*;
use sdde::util::fmt;

fn main() {
    // Each rank sends to (rank+1)%n and (rank+3)%n — a tiny sparse pattern.
    let topo = Topology::quartz(2, 4);
    let n = topo.nranks();
    let patterns: Vec<CrsvArgs> = (0..n)
        .map(|p| CrsvArgs {
            dest: {
                let mut d = vec![(p + 1) % n, (p + 3) % n];
                d.sort_unstable();
                d
            },
            sendcounts: vec![2, 2],
            sendvals: vec![
                (p * 10) as u64,
                (p * 10 + 1) as u64,
                (p * 100) as u64,
                (p * 100 + 1) as u64,
            ],
        })
        .collect();
    let patterns = Rc::new(patterns);

    for algo in SddeAlgorithm::VARIABLE {
        let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
        let pats = patterns.clone();
        let out = world.run(move |c| {
            let pats = pats.clone();
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(algo);
                alltoallv_crs(&mx, &info, &pats[c.rank()]).await.unwrap()
            }
        });
        println!(
            "algorithm {:<18} virtual time {:>10}  (inter-node msgs: {})",
            algo.name(),
            fmt::ns(out.end_time),
            out.counters.user_msgs[Tier::InterNode as usize],
        );
        if algo == SddeAlgorithm::Personalized {
            for (rank, res) in out.results.iter().enumerate() {
                println!(
                    "  rank {rank} receives from {:?}: {:?}",
                    res.src, res.recvvals
                );
            }
        }
    }
    println!("\nall algorithms returned identical results (asserted in tests)");
}
