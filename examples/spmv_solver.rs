//! **End-to-end driver** (DESIGN.md §E2E): all three layers composed on a
//! real small workload.
//!
//! 1. L3 (rust): 8 simulated ranks on 2 nodes form the SpMV communication
//!    pattern for a 64×64 Poisson problem with the paper's locality-aware
//!    non-blocking SDDE (`MPIX_Alltoallv_crs`).
//! 2. L2/L1 (AOT): every local SpMV inside distributed CG executes the
//!    XLA artifact compiled from the JAX model + Pallas Block-ELL kernel
//!    (`make artifacts`), loaded via PJRT from rust — Python is not
//!    running anywhere in this binary.
//! 3. The CG residual curve is printed (logged to EXPERIMENTS.md) and the
//!    XLA-kernel solution is verified against the pure-rust kernel and the
//!    sequential reference.
//!
//! Run: `make artifacts && cargo run --release --example spmv_solver`

use std::path::Path;
use std::rc::Rc;

use sdde::mpi::World;
use sdde::mpix::{MpixComm, MpixInfo, NeighborMethod, SddeAlgorithm};
use sdde::runtime::{Runtime, XlaLocal};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::solver::{cg, CsrLocal, DistMatrix};
use sdde::sparse::{form_neighborhood, MatrixPreset, Partition, SpmvPattern};
use sdde::util::fmt;

fn main() -> anyhow::Result<()> {
    let (nx, ny) = (64, 64);
    let preset = MatrixPreset::poisson2d(nx, ny);
    let topo = Topology::quartz(2, 4);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);

    println!("== E2E: distributed CG over SDDE-formed pattern, XLA local compute ==");
    println!(
        "poisson2d {nx}x{ny} (n={}), {} ranks ({} nodes x {} ppn)",
        preset.n, nranks, topo.nodes, topo.ppn
    );

    let rt = Rc::new(Runtime::load(Path::new("artifacts"))?);
    println!("loaded artifacts: spmv shapes {:?}", rt.spmv_shapes());

    // Exact solution x* = alternating pattern; b = A x*.
    let a_seq = preset.to_csr(0);
    let x_star: Vec<f64> = (0..preset.n).map(|i| ((i % 7) as f64) - 3.0).collect();
    let b_glob = a_seq.spmv(&x_star);
    let b_glob = Rc::new(b_glob);

    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let rt2 = rt.clone();
    let bg = b_glob.clone();
    let out = world.run(move |c| {
        let rt = rt2.clone();
        let bg = bg.clone();
        let preset = MatrixPreset::poisson2d(nx, ny);
        async move {
            // --- form the communication pattern with the paper's SDDE ---
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityNonBlocking);
            let pat = SpmvPattern::build(&preset, part, c.rank(), 0);
            let t0 = c.now();
            let (pkg, nc) = form_neighborhood(&mx, &info, &pat).await.unwrap();
            let sdde_time = c.now() - t0;

            // --- assemble the local block + the XLA kernel; every halo
            //     exchange inside CG runs on the persistent locality-aware
            //     neighborhood collective over the SDDE-formed graph ---
            let mut a = DistMatrix::build(&preset, part, c.rank(), 0, pkg);
            a.init_halo_over(&mx, &nc, NeighborMethod::Locality).await;
            let width = a.local.max_row_nnz().max(1);
            let ell = a.local.to_block_ell(128, width);
            let xla = XlaLocal::new(&rt, ell).expect("artifact fits");
            let (s, e) = part.range(c.rank());
            let b = bg[s..e].to_vec();

            // --- distributed CG with XLA local compute ---
            let t1 = c.now();
            let (x_xla, hist) = cg(&c, &a, &b, &xla, 400, 1e-8).await;
            let solve_time = c.now() - t1;

            // --- same solve with the pure-rust kernel for comparison ---
            let (x_rust, _) = cg(&c, &a, &b, &CsrLocal(&a.local), 400, 1e-8).await;

            (x_xla, x_rust, hist, sdde_time, solve_time)
        }
    });

    // Residual curve (identical on all ranks).
    let (_, _, hist, sdde_time, solve_time) = &out.results[0];
    println!("\nSDDE pattern formation: {}", fmt::ns(*sdde_time));
    println!(
        "CG: {} iterations, virtual solve time {}",
        hist.len() - 1,
        fmt::ns(*solve_time)
    );
    println!("residual curve (every 20 iters):");
    for (i, r) in hist.iter().enumerate() {
        if i % 20 == 0 || i + 1 == hist.len() {
            println!("  iter {i:>4}  ||r|| = {r:.6e}");
        }
    }

    // --- verification ---
    let x_xla: Vec<f64> = out.results.iter().flat_map(|r| r.0.clone()).collect();
    let x_rust: Vec<f64> = out.results.iter().flat_map(|r| r.1.clone()).collect();
    let max_vs_rust = x_xla
        .iter()
        .zip(&x_rust)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let max_vs_star = x_xla
        .iter()
        .zip(&x_star)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("\nmax |x_xla - x_rust|  = {max_vs_rust:.3e} (f32 kernel vs f64 kernel)");
    println!("max |x_xla - x_star|  = {max_vs_star:.3e} (vs exact solution)");
    anyhow::ensure!(max_vs_rust < 5e-2, "XLA and rust kernels diverged");
    anyhow::ensure!(max_vs_star < 5e-2, "solver failed to converge to x*");
    let final_rel = hist.last().unwrap() / hist[0];
    anyhow::ensure!(final_rel < 1e-7, "residual reduction only {final_rel:.1e}");
    println!(
        "\nE2E OK: SDDE pattern -> persistent neighbor halo -> XLA/Pallas local SpMV -> converged CG"
    );
    Ok(())
}
