//! Conservation laws of the trace subsystem (DESIGN.md invariant 5):
//!
//! * every traced send is consumed by exactly one traced receive (matched
//!   by `msg_id`);
//! * the trace rollup agrees **bit-for-bit** with the independent legacy
//!   `Counters` accounting on every shared metric;
//! * the trace-derived red-dot metric reproduces the paper's locality
//!   invariant (aggregated < direct) on the Figs. 5–8 quick configs and
//!   the steady-state neighbor bench;
//! * tracing is observational only: a disabled world records zero events,
//!   and enabling tracing never changes virtual time.

use std::collections::HashMap;
use std::rc::Rc;

use sdde::bench::figures::{run_once, run_once_traced, Variant};
use sdde::bench::{run_halo_once, HaloMethod};
use sdde::mpi::{Payload, ReduceOp, World};
use sdde::mpix::{IntraAlgo, SddeAlgorithm};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::sparse::{MatrixPreset, Partition, SpmvPattern};
use sdde::trace::{EventKind, TraceConfig, TraceSummary};

fn patterns(preset: &MatrixPreset, topo: &Topology, seed: u64) -> Rc<Vec<SpmvPattern>> {
    let part = Partition::new(preset.n, topo.nranks());
    Rc::new(
        (0..topo.nranks())
            .map(|r| SpmvPattern::build(preset, part, r, seed))
            .collect(),
    )
}

/// Mixed workload touching every instrumented code path: eager and
/// rendezvous p2p, unexpected-queue hits, collectives, RMA, CPU charges.
fn mixed_workload(trace: TraceConfig) -> sdde::mpi::RunOutput<u64> {
    let world = World::with_trace(
        Topology::quartz(2, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
        trace,
    );
    world.run(|c| async move {
        let n = c.nranks();
        let me = c.rank();
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        // Eager (small) and rendezvous (large) sends around the ring.
        let r1 = c.isend(next, 1, Payload::ints(&[me as u64])).await;
        let r2 = c.isend(next, 2, Payload::longs(&vec![me as u64; 4096])).await;
        // Force an unexpected-queue hit: let the messages land first.
        c.sim().sleep(5_000_000).await;
        c.recv(prev, 1).await;
        c.recv(prev, 2).await;
        r1.await;
        r2.await;
        // Collectives and CPU.
        let s = c.allreduce(vec![me as u64], ReduceOp::Sum).await;
        c.charge_cpu(10_000).await;
        c.barrier().await;
        // RMA.
        let win = c.win_allocate(n).await;
        win.fence().await;
        win.put((me + 1) % n, me, &[me as u64], 4).await;
        win.fence().await;
        s[0]
    })
}

#[test]
fn disabled_world_records_zero_events() {
    let out = mixed_workload(TraceConfig::off());
    assert!(out.trace.is_empty());
    assert!(out.trace.events.is_empty());
    assert!(out.trace.summary.is_empty());
    assert_eq!(out.trace.summary.internode_sent.len(), 0);
    // ...while the legacy counters still saw the traffic.
    assert!(out.counters.total_user_msgs() > 0);
}

#[test]
fn tracing_never_changes_virtual_time() {
    let off = mixed_workload(TraceConfig::off());
    let counters = mixed_workload(TraceConfig::counters_only());
    let full = mixed_workload(TraceConfig::full());
    assert_eq!(off.end_time, counters.end_time);
    assert_eq!(off.end_time, full.end_time);
    assert_eq!(off.results, full.results);
    assert!(full.trace.events.len() > counters.trace.events.len());
}

#[test]
fn summary_mirrors_legacy_counters_bit_for_bit() {
    let out = mixed_workload(TraceConfig::full());
    let s = &out.trace.summary;
    let c = &out.counters;
    assert_eq!(s.user_msgs(), c.user_msgs);
    assert_eq!(s.user_bytes(), c.user_bytes);
    assert_eq!(s.internal_msgs(), c.int_msgs);
    assert_eq!(s.internal_bytes(), c.int_bytes);
    assert_eq!(s.internode_sent, c.internode_sent);
    assert_eq!(s.rma_puts, c.rma_puts);
    // The live rollup and the from-events recomputation are one rule.
    assert_eq!(
        *s,
        TraceSummary::from_events(&out.trace.events, out.counters.internode_sent.len())
    );
}

#[test]
fn every_send_matches_exactly_one_recv() {
    let out = mixed_workload(TraceConfig::full());
    let mut sends: HashMap<u64, u32> = HashMap::new();
    let mut recvs: HashMap<u64, u32> = HashMap::new();
    for e in &out.trace.events {
        match e.kind {
            EventKind::EagerSend | EventKind::RendezvousSend => {
                assert_ne!(e.msg_id, 0, "traced send without msg_id: {e:?}");
                *sends.entry(e.msg_id).or_default() += 1;
            }
            EventKind::RecvMatch | EventKind::UnexpectedHit => {
                assert_ne!(e.msg_id, 0, "traced recv without msg_id: {e:?}");
                *recvs.entry(e.msg_id).or_default() += 1;
            }
            _ => {}
        }
    }
    assert!(!sends.is_empty());
    for (id, n) in &sends {
        assert_eq!(*n, 1, "msg {id} sent {n} times");
        assert_eq!(
            recvs.get(id),
            Some(&1),
            "msg {id} received {:?} times",
            recvs.get(id).copied().unwrap_or(0)
        );
    }
    assert_eq!(sends.len(), recvs.len(), "receives without a send");
    // The deliberate unexpected-queue phase really exercised both paths.
    assert!(out.trace.summary.unexpected_hits > 0);
    assert!(out.trace.summary.posted_matches > 0);
}

/// Send↔recv conservation holds on a real SDDE too (both variants).
#[test]
fn sdde_trace_conserves_messages() {
    let preset = MatrixPreset::cage14_like().scaled(400);
    let topo = Topology::quartz(2, 4);
    let pats = patterns(&preset, &topo, 7);
    for variant in [Variant::ConstSize, Variant::Variable] {
        let (_, trace) = run_once_traced(
            topo.clone(),
            MpiFlavor::Mvapich2,
            SddeAlgorithm::LocalityNonBlocking,
            RegionKind::Node,
            IntraAlgo::Personalized,
            variant,
            pats.clone(),
        );
        assert!(!trace.events.is_empty());
        let mut balance: HashMap<u64, i64> = HashMap::new();
        for e in &trace.events {
            match e.kind {
                EventKind::EagerSend | EventKind::RendezvousSend => {
                    *balance.entry(e.msg_id).or_default() += 1;
                }
                EventKind::RecvMatch | EventKind::UnexpectedHit => {
                    *balance.entry(e.msg_id).or_default() -= 1;
                }
                _ => {}
            }
        }
        for (id, b) in &balance {
            assert_eq!(*b, 0, "{variant:?}: msg {id} send/recv imbalance {b}");
        }
    }
}

/// The paper's locality invariant (aggregated sends fewer inter-node
/// messages than direct) is visible through the trace rollup on every
/// figure's quick configuration — same numbers figures_smoke asserts on.
#[test]
fn locality_invariant_holds_in_trace_for_all_figures() {
    use sdde::bench::FigureId;
    let preset = MatrixPreset::cage14_like().scaled(200);
    let topo = Topology::quartz(4, 8);
    let pats = patterns(&preset, &topo, 2023);
    for fig in [FigureId::Fig5, FigureId::Fig6, FigureId::Fig7, FigureId::Fig8] {
        let run = |algo| {
            let (_, summary) = run_once(
                topo.clone(),
                fig.flavor(),
                algo,
                RegionKind::Node,
                IntraAlgo::Personalized,
                fig.variant(),
                pats.clone(),
            );
            summary.max_internode_per_rank()
        };
        let direct = run(SddeAlgorithm::NonBlocking);
        let agg = run(SddeAlgorithm::LocalityNonBlocking);
        assert!(
            agg < direct,
            "{fig:?}: aggregated {agg} not below direct {direct}"
        );
    }
}

/// Steady-state neighbor bench: the trace-derived per-rank inter-node
/// counts reproduce the locality effect there too.
#[test]
fn locality_invariant_holds_in_trace_for_neighbor_bench() {
    let preset = Rc::new(MatrixPreset::cage14_like().scaled(200));
    let topo = Topology::quartz(4, 4);
    let run = |method| {
        let (_, _, sent) = run_halo_once(
            topo.clone(),
            MpiFlavor::Mvapich2,
            SddeAlgorithm::NonBlocking,
            RegionKind::Node,
            method,
            4,
            preset.clone(),
            9,
        );
        sent
    };
    let direct = run(HaloMethod::Persistent);
    let agg = run(HaloMethod::LocalityPersistent);
    assert!(agg > 0, "traced counts must be live, not zero");
    assert!(agg < direct, "aggregated {agg} not below direct {direct}");
}

/// The live per-rank accessor agrees with the legacy counters at every
/// observation point, not just at the end of the run.
#[test]
fn live_internode_accessor_matches_counters() {
    let world = World::with_trace(
        Topology::quartz(2, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
        TraceConfig::counters_only(),
    );
    let out = world.run(|c| async move {
        let n = c.nranks();
        let me = c.rank();
        for k in 0..3u64 {
            c.send((me + 1) % n, 5, Payload::ints(&[k])).await;
            c.recv((me + n - 1) % n, 5).await;
            assert_eq!(
                c.traced_internode_sent(me),
                c.counters().internode_sent[me],
                "divergence at step {k}"
            );
        }
        c.barrier().await;
        true
    });
    assert!(out.results.iter().all(|&ok| ok));
    assert_eq!(
        out.trace.summary.internode_sent,
        out.counters.internode_sent
    );
}
