//! The parallel sweep engine must be invisible in the results: running a
//! sweep with jobs=1 and jobs=4 has to produce identical point vectors,
//! identical CSV bytes, and identical (collected) progress output. This
//! is the determinism contract that lets CI and users crank `--jobs`
//! without re-validating figures.

use sdde::bench::{
    run_cells, run_neighbor_sweep_bench, run_sweep_bench, write_csv, write_neighbor_csv,
    FigureId, NeighborSweepConfig, ProgressSink, SweepConfig,
};
use sdde::simnet::MpiFlavor;

#[test]
fn figure_sweep_is_jobs_invariant() {
    let mut cfg = SweepConfig::quick(FigureId::Fig7, 400);
    cfg.nodes = vec![2, 4];
    cfg.matrices.truncate(2);
    cfg.progress = ProgressSink::Collected;

    cfg.jobs = 1;
    let (serial, bench1) = run_sweep_bench(&cfg);
    cfg.jobs = 4;
    let (parallel, bench4) = run_sweep_bench(&cfg);

    assert!(!serial.is_empty());
    assert_eq!(serial, parallel, "points differ between jobs=1 and jobs=4");
    assert_eq!(bench1.cells.len(), bench4.cells.len());
    // Simulated work is identical; only host wall time may differ.
    assert_eq!(bench1.events_run(), bench4.events_run());
    assert_eq!(bench1.polls(), bench4.polls());

    // CSV bytes, the artifact CI diffs.
    let dir = std::env::temp_dir();
    let p1 = dir.join("sdde_par_det_serial.csv");
    let p4 = dir.join("sdde_par_det_parallel.csv");
    write_csv(&p1, &serial).unwrap();
    write_csv(&p4, &parallel).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b4 = std::fs::read(&p4).unwrap();
    assert_eq!(b1, b4, "CSV bytes differ between jobs=1 and jobs=4");
    std::fs::remove_file(p1).ok();
    std::fs::remove_file(p4).ok();
}

#[test]
fn neighbor_sweep_is_jobs_invariant() {
    let mut cfg = NeighborSweepConfig::quick(MpiFlavor::Mvapich2, 400);
    cfg.nodes = vec![2];
    cfg.matrices.truncate(1);
    cfg.iters = vec![1, 8];
    cfg.progress = ProgressSink::Collected;

    cfg.jobs = 1;
    let (serial, _) = run_neighbor_sweep_bench(&cfg);
    cfg.jobs = 4;
    let (parallel, _) = run_neighbor_sweep_bench(&cfg);

    assert!(!serial.is_empty());
    assert_eq!(serial, parallel);

    let dir = std::env::temp_dir();
    let p1 = dir.join("sdde_par_det_nb_serial.csv");
    let p4 = dir.join("sdde_par_det_nb_parallel.csv");
    write_neighbor_csv(&p1, &serial).unwrap();
    write_neighbor_csv(&p4, &parallel).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p4).unwrap());
    std::fs::remove_file(p1).ok();
    std::fs::remove_file(p4).ok();
}

#[test]
fn progress_lines_are_jobs_invariant() {
    // The engine's ordered flush: collected lines must come out in cell
    // index order regardless of completion order.
    let work = |i: usize, p: &mut sdde::bench::Progress| {
        p.line(format!("[cell {i}] begin"));
        // Skew completion order: later cells finish earlier.
        let spins = (32 - i) * 20_000;
        let mut acc = 1u64;
        for k in 0..spins {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k as u64);
        }
        p.line(format!("[cell {i}] end acc={acc}"));
        acc
    };
    let (r1, l1) = run_cells(1, 32, ProgressSink::Collected, work);
    let (r8, l8) = run_cells(8, 32, ProgressSink::Collected, work);
    assert_eq!(r1, r8);
    assert_eq!(l1, l8);
    assert_eq!(l1.len(), 64);
    for (i, chunk) in l1.chunks(2).enumerate() {
        assert!(chunk[0].starts_with(&format!("[cell {i}] begin")));
    }
}
