//! Integration: SDDE-formed communication packages drive real distributed
//! solves on the paper-matrix analogs, and the PJRT runtime round-trips
//! the AOT artifacts (the rust half of the L1/L2/L3 composition).

use std::path::Path;
use std::rc::Rc;

use sdde::mpi::World;
use sdde::mpix::{MpixComm, MpixInfo, SddeAlgorithm};
use sdde::runtime::{Runtime, XlaLocal};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::solver::{cg, jacobi, CsrLocal, DistMatrix, LocalSpmv};
use sdde::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};

/// Jacobi on every paper-matrix analog (scaled), pattern formed by every
/// SDDE algorithm — residuals must agree across algorithms bit-for-bit
/// (they form identical packages).
#[test]
fn jacobi_converges_all_matrices_all_algorithms() {
    for preset in MatrixPreset::paper_set() {
        let preset = preset.scaled(3000);
        let topo = Topology::quartz(2, 4);
        let part = Partition::new(preset.n, topo.nranks());
        let mut baseline: Option<Vec<f64>> = None;
        for algo in SddeAlgorithm::VARIABLE {
            let preset2 = preset.clone();
            let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
            let out = world.run(move |c| {
                let preset = preset2.clone();
                async move {
                    let mx = MpixComm::new(c.clone(), RegionKind::Node);
                    let info = MpixInfo::with_algorithm(algo);
                    let pat = SpmvPattern::build(&preset, part, c.rank(), 4);
                    let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                    let a = DistMatrix::build(&preset, part, c.rank(), 4, pkg);
                    let b = vec![1.0; a.local_n()];
                    let (_, hist) = jacobi(&c, &a, &b, &CsrLocal(&a.local), 25, 1.0).await;
                    hist
                }
            });
            let hist = out.results[0].clone();
            assert!(
                hist.last().unwrap() / hist[0] < 1e-5,
                "{} with {algo:?}: {hist:?}",
                preset.name
            );
            match &baseline {
                None => baseline = Some(hist),
                Some(b) => assert_eq!(
                    b, &hist,
                    "{}: {algo:?} changed numerics",
                    preset.name
                ),
            }
        }
    }
}

/// The XLA artifact computes the same SpMV as the rust ELL reference
/// (requires `make artifacts`; run as part of `make test`).
#[test]
fn xla_artifact_matches_ell_reference() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/manifest.txt missing (run `make artifacts`)");
        return;
    }
    let rt = Runtime::load(dir).expect("load artifacts");
    let preset = MatrixPreset::poisson2d(16, 16);
    let a = preset.to_csr(0);
    let width = a.max_row_nnz();
    let ell = a.to_block_ell(128, width);
    let xlen_needed = ell.ncols;
    let x: Vec<f64> = (0..xlen_needed).map(|i| (i % 17) as f64 - 8.0).collect();
    let expect: Vec<f32> = {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        ell.spmv_ref(&xf)
    };
    let xla = XlaLocal::new(&rt, ell.clone()).expect("artifact fits");
    let got = xla.apply(&x);
    assert_eq!(got.len(), ell.nrows);
    for i in 0..ell.nrows {
        assert!(
            (got[i] - expect[i] as f64).abs() < 1e-3,
            "row {i}: {} vs {}",
            got[i],
            expect[i]
        );
    }
}

/// dot artifact round-trip.
#[test]
fn xla_dot_artifact() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let rt = Runtime::load(dir).expect("load artifacts");
    let n = 256;
    let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01).collect();
    let b: Vec<f32> = (0..n).map(|i| 1.0 - (i as f32) * 0.005).collect();
    let got = rt.run_dot(n, &a, &b).expect("dot runs");
    let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    assert!((got - want).abs() < 1e-2, "{got} vs {want}");
}

/// CG through the full stack (smaller than the example; asserts the same
/// composition in CI).
#[test]
fn cg_with_xla_kernel_matches_rust_kernel() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let rt = Rc::new(Runtime::load(dir).expect("load artifacts"));
    let preset = MatrixPreset::poisson2d(16, 16);
    let topo = Topology::quartz(1, 4);
    let part = Partition::new(preset.n, topo.nranks());
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let rt2 = rt.clone();
    let out = world.run(move |c| {
        let rt = rt2.clone();
        let preset = MatrixPreset::poisson2d(16, 16);
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::NonBlocking);
            let pat = SpmvPattern::build(&preset, part, c.rank(), 0);
            let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
            let a = DistMatrix::build(&preset, part, c.rank(), 0, pkg);
            let width = a.local.max_row_nnz().max(1);
            let ell = a.local.to_block_ell(128, width);
            let xla = XlaLocal::new(&rt, ell).expect("fits");
            let b = vec![1.0; a.local_n()];
            let (x1, h1) = cg(&c, &a, &b, &xla, 300, 1e-8).await;
            let (x2, _) = cg(&c, &a, &b, &CsrLocal(&a.local), 300, 1e-8).await;
            (x1, x2, h1)
        }
    });
    for (x1, x2, h1) in &out.results {
        assert!(h1.last().unwrap() / h1[0] < 1e-7);
        for (a, b) in x1.iter().zip(x2) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }
}
