//! Smoke tests of the figure harness at reduced scale: the paper's
//! *qualitative* claims must hold in the simulator (who wins, where, and
//! the red-dot reduction) without running the multi-minute full sweep.

use sdde::bench::{render_figure, run_sweep, write_csv, FigureId, SweepConfig, Variant};
use sdde::mpix::SddeAlgorithm;
use sdde::sparse::MatrixPreset;

fn quick(fig: FigureId, div: usize, nodes: Vec<usize>) -> SweepConfig {
    let mut cfg = SweepConfig::quick(fig, div);
    cfg.nodes = nodes;
    cfg
}

#[test]
fn fig7_shape_locality_wins_on_high_message_matrix() {
    // cage14-like at the largest quick scale: a locality-aware variant
    // must beat both standard variants (paper §V: up to 20x at scale).
    let mut cfg = quick(FigureId::Fig7, 64, vec![8]);
    cfg.ppn = 16;
    cfg.matrices = vec![MatrixPreset::cage14_like().scaled(64)];
    let pts = run_sweep(&cfg);
    let t = |name: &str| pts.iter().find(|p| p.algo == name).unwrap().time_ns;
    let best_std = t("personalized").min(t("nonblocking"));
    let best_loc = t("loc-personalized").min(t("loc-nonblocking"));
    assert!(
        best_loc < best_std,
        "locality-aware {best_loc} not faster than standard {best_std}"
    );
}

#[test]
fn fig7_shape_locality_loses_on_low_message_matrix() {
    // dielFilterV2clx-like: the standard non-blocking method should win
    // (paper §V: "incurring slowdown for matrices that require few
    // messages").
    let mut cfg = quick(FigureId::Fig7, 64, vec![8]);
    cfg.ppn = 16;
    cfg.matrices = vec![MatrixPreset::dielfilterv2clx_like().scaled(64)];
    let pts = run_sweep(&cfg);
    let t = |name: &str| pts.iter().find(|p| p.algo == name).unwrap().time_ns;
    let best_std = t("personalized").min(t("nonblocking"));
    let best_loc = t("loc-personalized").min(t("loc-nonblocking"));
    assert!(
        best_std < best_loc,
        "standard {best_std} should beat locality-aware {best_loc} on dielFilter-like"
    );
}

#[test]
fn red_dots_aggregated_bounded_by_nodes() {
    let mut cfg = quick(FigureId::Fig5, 128, vec![4, 8]);
    cfg.matrices = vec![MatrixPreset::cage14_like().scaled(128)];
    cfg.algos = vec![
        SddeAlgorithm::NonBlocking,
        SddeAlgorithm::LocalityNonBlocking,
    ];
    let pts = run_sweep(&cfg);
    for p in &pts {
        if p.algo == "loc-nonblocking" {
            assert!(
                p.max_internode < p.nodes as u64,
                "aggregated count {} at {} nodes",
                p.max_internode,
                p.nodes
            );
        }
    }
    // aggregation reduced the count vs the standard method at same scale
    for nodes in [4usize, 8] {
        let std = pts
            .iter()
            .find(|p| p.nodes == nodes && p.algo == "nonblocking")
            .unwrap()
            .max_internode;
        let agg = pts
            .iter()
            .find(|p| p.nodes == nodes && p.algo == "loc-nonblocking")
            .unwrap()
            .max_internode;
        assert!(agg <= std, "nodes={nodes}: agg {agg} > std {std}");
    }
}

#[test]
fn const_and_variable_variants_both_run_rma_only_in_const() {
    let cfg5 = quick(FigureId::Fig5, 256, vec![2]);
    let pts5 = run_sweep(&cfg5);
    assert!(pts5.iter().any(|p| p.algo == "rma"));
    let cfg7 = quick(FigureId::Fig7, 256, vec![2]);
    let pts7 = run_sweep(&cfg7);
    assert!(!pts7.iter().any(|p| p.algo == "rma"));
}

#[test]
fn render_and_csv_pipeline() {
    let mut cfg = quick(FigureId::Fig6, 256, vec![2]);
    cfg.matrices.truncate(1);
    let pts = run_sweep(&cfg);
    let rendered = render_figure(&FigureId::Fig6.title(), &pts);
    assert!(rendered.contains("Figure 6"));
    assert!(rendered.contains("openmpi"));
    assert!(rendered.contains("speedup"));
    let path = std::env::temp_dir().join("sdde_fig_smoke.csv");
    write_csv(&path, &pts).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    assert_eq!(csv.lines().count(), pts.len() + 1);
    std::fs::remove_file(path).ok();
}

#[test]
fn openmpi_and_mvapich_differ_but_agree_on_ranking_at_scale() {
    // Same workload, two MPI presets: absolute times differ, the winner at
    // the largest scale is stable (paper: consistent across both MPIs).
    let mk = |fig| {
        let mut cfg = quick(fig, 64, vec![8]);
        cfg.ppn = 16;
        cfg.matrices = vec![MatrixPreset::cage14_like().scaled(64)];
        run_sweep(&cfg)
    };
    let mv = mk(FigureId::Fig7);
    let om = mk(FigureId::Fig8);
    let winner = |pts: &[sdde::bench::Point]| {
        pts.iter()
            .min_by_key(|p| p.time_ns)
            .map(|p| p.algo)
            .unwrap()
    };
    let (wm, wo) = (winner(&mv), winner(&om));
    assert!(
        wm.starts_with("loc-") && wo.starts_with("loc-"),
        "winners: mvapich2={wm} openmpi={wo}"
    );
    // absolute times differ between presets
    let tm: u64 = mv.iter().map(|p| p.time_ns).sum();
    let to: u64 = om.iter().map(|p| p.time_ns).sum();
    assert_ne!(tm, to);
}

#[test]
fn variant_enum_consistency() {
    assert_eq!(FigureId::Fig5.variant(), Variant::ConstSize);
    assert_eq!(FigureId::Fig7.variant(), Variant::Variable);
}
