//! Integration tests of the `mpix::neighbor` subsystem (paper invariant 2
//! in DESIGN.md): the persistent neighbor-alltoallv SpMV must agree
//! bit-for-bit with the legacy p2p halo path for every pattern-formation
//! algorithm, survive thousands of back-to-back exchanges on fixed tags,
//! and keep overlapping exchanges isolated.

use std::rc::Rc;

use sdde::bench::{run_halo_once, HaloMethod};
use sdde::mpi::World;
use sdde::mpix::{
    alltoallv_crs, MpixComm, MpixInfo, NeighborAlltoallv, NeighborComm, NeighborMethod,
    SddeAlgorithm,
};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::solver::{jacobi, CsrLocal, DistMatrix};
use sdde::sparse::{form_commpkg, form_neighborhood, MatrixPreset, Partition, SpmvPattern};

fn world(nodes: usize, ppn: usize, flavor: MpiFlavor) -> World {
    World::new(Topology::quartz(nodes, ppn), CostModel::preset(flavor))
}

/// Persistent SpMV (standard and locality-aware) agrees bit-for-bit with
/// the legacy p2p halo path for every `SddeAlgorithm::VARIABLE` pattern,
/// and matches the sequential oracle.
#[test]
fn persistent_spmv_agrees_bitwise_with_p2p_all_algorithms() {
    let preset = MatrixPreset::poisson2d(16, 12);
    let topo = Topology::quartz(2, 4);
    let part = Partition::new(preset.n, topo.nranks());
    let a_seq = preset.to_csr(3);
    let x_glob: Vec<f64> = (0..preset.n).map(|i| (i % 13) as f64 - 6.0).collect();
    let y_expect = a_seq.spmv(&x_glob);

    for algo in SddeAlgorithm::VARIABLE {
        let wrld = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
        let preset2 = Rc::new(preset.clone());
        let xg = Rc::new(x_glob.clone());
        let out = wrld.run(move |c| {
            let preset = preset2.clone();
            let xg = xg.clone();
            async move {
                let rank = c.rank();
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(algo);
                let pat = SpmvPattern::build(&preset, part, rank, 3);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let (s, e) = part.range(rank);

                let a_p2p = DistMatrix::build(&preset, part, rank, 3, pkg.clone());
                let y_p2p = a_p2p.spmv(&c, &xg[s..e]).await;

                let mut a_std = DistMatrix::build(&preset, part, rank, 3, pkg.clone());
                a_std.init_halo(&mx, NeighborMethod::Standard).await;
                let y_std = a_std.spmv(&c, &xg[s..e]).await;

                let mut a_loc = DistMatrix::build(&preset, part, rank, 3, pkg);
                a_loc.init_halo(&mx, NeighborMethod::Locality).await;
                let y_loc = a_loc.spmv(&c, &xg[s..e]).await;

                (y_p2p, y_std, y_loc)
            }
        });
        let mut row = 0usize;
        for (y_p2p, y_std, y_loc) in &out.results {
            for i in 0..y_p2p.len() {
                assert_eq!(
                    y_p2p[i].to_bits(),
                    y_std[i].to_bits(),
                    "algo {algo:?}: standard diverged at local row {i}"
                );
                assert_eq!(
                    y_p2p[i].to_bits(),
                    y_loc[i].to_bits(),
                    "algo {algo:?}: locality diverged at local row {i}"
                );
                assert!(
                    (y_p2p[i] - y_expect[row]).abs() < 1e-12,
                    "algo {algo:?} row {row}: {} vs {}",
                    y_p2p[i],
                    y_expect[row]
                );
                row += 1;
            }
        }
        assert_eq!(row, y_expect.len());
    }
}

/// ≥ 2048 back-to-back exchanges on every halo engine with
/// iteration-dependent data: fixed persistent tags (and the widened legacy
/// tag window) must never cross-talk between iterations.
#[test]
fn repeated_exchanges_survive_2048_iterations_without_tag_collisions() {
    const ITERS: usize = 2100; // > 2048, and > the old 1024-tag window
    let preset = MatrixPreset::poisson2d(8, 8);
    let topo = Topology::quartz(2, 2);
    let part = Partition::new(preset.n, topo.nranks());
    for method in [None, Some(NeighborMethod::Standard), Some(NeighborMethod::Locality)] {
        let wrld = World::new(topo.clone(), CostModel::preset(MpiFlavor::OpenMpi));
        let preset2 = Rc::new(preset.clone());
        let out = wrld.run(move |c| {
            let preset = preset2.clone();
            async move {
                let rank = c.rank();
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::NonBlocking);
                let pat = SpmvPattern::build(&preset, part, rank, 0);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let mut a = DistMatrix::build(&preset, part, rank, 0, pkg);
                if let Some(m) = method {
                    a.init_halo(&mx, m).await;
                }
                let (s, e) = part.range(rank);
                for it in 0..ITERS {
                    // Iteration-tagged values: any message leaking across
                    // iterations lands a wrong value in some ghost slot.
                    let x: Vec<f64> = (s..e).map(|g| (it * 31 + g) as f64).collect();
                    let x_ext = a.halo_exchange(&c, &x).await;
                    for (k, &gcol) in a.ghost_cols.iter().enumerate() {
                        assert_eq!(
                            x_ext[a.local_n() + k],
                            (it * 31 + gcol) as f64,
                            "method {method:?} iter {it}: ghost {gcol} stale"
                        );
                    }
                }
                ITERS
            }
        });
        assert!(out.results.iter().all(|&r| r == ITERS));
    }
}

/// Overlapping exchanges (start A, start B, wait A, wait B) on one
/// persistent request stay isolated — no per-iteration tags needed.
#[test]
fn overlapping_persistent_exchanges_do_not_crosstalk() {
    for method in [NeighborMethod::Standard, NeighborMethod::Locality] {
        let out = world(2, 2, MpiFlavor::Mvapich2).run(move |c| async move {
            let n = c.nranks();
            let me = c.rank();
            let next = (me + 1) % n;
            let prev = (me + n - 1) % n;
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let nc = NeighborComm::create_adjacent(
                c.clone(),
                mx.region_kind(),
                vec![(prev, 2)],
                vec![(next, 2)],
            );
            let pa = NeighborAlltoallv::init(&mx, &nc, method).await;
            let xa = [me as f64, 100.0 + me as f64];
            let xb = [1000.0 + me as f64, 2000.0 + me as f64];
            let ea = pa.start(&xa).await;
            let eb = pa.start(&xb).await;
            let ra = pa.wait(ea).await;
            let rb = pa.wait(eb).await;
            assert_eq!(ra, vec![prev as f64, 100.0 + prev as f64], "{method:?} A");
            assert_eq!(
                rb,
                vec![1000.0 + prev as f64, 2000.0 + prev as f64],
                "{method:?} B"
            );
            true
        });
        assert!(out.results.iter().all(|&ok| ok));
    }
}

/// The locality-aware engine forwards intra-region data *inside* `wait`,
/// so waiting exchanges out of start order would push exchange B's
/// forwards into exchange A's posted forward receives. That hazard must be
/// detected and refused, not silently corrupt data.
#[test]
#[should_panic(expected = "out of start order")]
fn locality_out_of_order_wait_panics() {
    world(2, 2, MpiFlavor::Mvapich2).run(move |c| async move {
        let n = c.nranks();
        let me = c.rank();
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        let mx = MpixComm::new(c.clone(), RegionKind::Node);
        let nc = NeighborComm::create_adjacent(
            c.clone(),
            mx.region_kind(),
            vec![(prev, 1)],
            vec![(next, 1)],
        );
        let pa = NeighborAlltoallv::init(&mx, &nc, NeighborMethod::Locality).await;
        let ea = pa.start(&[me as f64]).await;
        let eb = pa.start(&[10.0 + me as f64]).await;
        let _rb = pa.wait(eb).await; // newer exchange first: must panic
        let _ra = pa.wait(ea).await;
    });
}

/// The standard engine has no wait-order constraint (matching is purely
/// posted-order): waiting B before A returns each exchange's own data.
#[test]
fn standard_out_of_order_wait_is_allowed() {
    let out = world(2, 2, MpiFlavor::Mvapich2).run(move |c| async move {
        let n = c.nranks();
        let me = c.rank();
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        let mx = MpixComm::new(c.clone(), RegionKind::Node);
        let nc = NeighborComm::create_adjacent(
            c.clone(),
            mx.region_kind(),
            vec![(prev, 1)],
            vec![(next, 1)],
        );
        let pa = NeighborAlltoallv::init(&mx, &nc, NeighborMethod::Standard).await;
        let ea = pa.start(&[me as f64]).await;
        let eb = pa.start(&[10.0 + me as f64]).await;
        let rb = pa.wait(eb).await;
        let ra = pa.wait(ea).await;
        assert_eq!(ra, vec![prev as f64], "A data");
        assert_eq!(rb, vec![10.0 + prev as f64], "B data");
        true
    });
    assert!(out.results.iter().all(|&ok| ok));
}

/// `form_neighborhood` hands back a NeighborComm whose adjacency is the
/// package itself, and the raw-SDDE constructor agrees with it.
#[test]
fn neighbor_comm_constructors_agree_with_commpkg() {
    let preset = MatrixPreset::fault_639_like().scaled(2000);
    let topo = Topology::quartz(2, 3);
    let part = Partition::new(preset.n, topo.nranks());
    let preset2 = Rc::new(preset);
    let out = world(2, 3, MpiFlavor::Mvapich2).run(move |c| {
        let preset = preset2.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::Personalized);
            let pat = SpmvPattern::build(&preset, part, c.rank(), 11);
            let (pkg, nc) = form_neighborhood(&mx, &info, &pat).await.unwrap();

            // from_commpkg: sources/dests mirror the package.
            let src_ok = nc
                .sources()
                .iter()
                .zip(&pkg.recv_from)
                .all(|(&(s, cnt), (owner, cols))| s == *owner && cnt == cols.len());
            let dst_ok = nc
                .dests()
                .iter()
                .zip(&pkg.send_to)
                .all(|(&(d, cnt), (nbr, rows))| d == *nbr && cnt == rows.len());

            // from_crsv over the raw SDDE call builds the same graph.
            let args = pat.crsv_args();
            let res = alltoallv_crs(&mx, &info, &args).await.unwrap();
            let nc2 = NeighborComm::from_crsv(&mx, &args, &res);
            let same = nc2.sources() == nc.sources() && nc2.dests() == nc.dests();

            src_ok && dst_ok && same && nc.sources().len() == pkg.recv_from.len()
        }
    });
    assert!(out.results.iter().all(|&ok| ok));
}

/// Jacobi over the persistent locality-aware halo reproduces the p2p
/// residual history bit-for-bit (identical arithmetic, different wires).
#[test]
fn jacobi_history_identical_across_halo_engines() {
    let preset = MatrixPreset::poisson2d(12, 10);
    let topo = Topology::quartz(2, 4);
    let part = Partition::new(preset.n, topo.nranks());
    let preset2 = Rc::new(preset);
    let out = world(2, 4, MpiFlavor::Mvapich2).run(move |c| {
        let preset = preset2.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityNonBlocking);
            let pat = SpmvPattern::build(&preset, part, c.rank(), 5);
            let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();

            let a_p2p = DistMatrix::build(&preset, part, c.rank(), 5, pkg.clone());
            let b = vec![1.0; a_p2p.local_n()];
            let (_, h_p2p) = jacobi(&c, &a_p2p, &b, &CsrLocal(&a_p2p.local), 25, 1.0).await;

            let mut a_loc = DistMatrix::build(&preset, part, c.rank(), 5, pkg);
            a_loc.init_halo(&mx, NeighborMethod::Locality).await;
            let (_, h_loc) = jacobi(&c, &a_loc, &b, &CsrLocal(&a_loc.local), 25, 1.0).await;

            (h_p2p, h_loc)
        }
    });
    for (h_p2p, h_loc) in &out.results {
        assert_eq!(h_p2p.len(), h_loc.len());
        for (a, b) in h_p2p.iter().zip(h_loc) {
            assert_eq!(a.to_bits(), b.to_bits(), "residual history diverged");
        }
        assert!(
            h_p2p.last().unwrap() < &(h_p2p[0] * 1e-3),
            "jacobi failed to converge: {h_p2p:?}"
        );
    }
}

/// Socket-granularity regions work end to end in the steady state too.
#[test]
fn persistent_locality_socket_regions_agree() {
    let preset = MatrixPreset::poisson2d(10, 8);
    let topo = Topology::quartz(2, 6);
    let part = Partition::new(preset.n, topo.nranks());
    let preset2 = Rc::new(preset.clone());
    let a_seq = preset.to_csr(1);
    let x_glob: Vec<f64> = (0..preset.n).map(|i| (i % 7) as f64).collect();
    let y_expect = a_seq.spmv(&x_glob);
    let xg = Rc::new(x_glob);
    let out = world(2, 6, MpiFlavor::OpenMpi).run(move |c| {
        let preset = preset2.clone();
        let xg = xg.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Socket);
            let info = MpixInfo {
                algorithm: SddeAlgorithm::LocalityPersonalized,
                region: RegionKind::Socket,
                ..MpixInfo::default()
            };
            let pat = SpmvPattern::build(&preset, part, c.rank(), 1);
            let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
            let mut a = DistMatrix::build(&preset, part, c.rank(), 1, pkg);
            a.init_halo(&mx, NeighborMethod::Locality).await;
            let (s, e) = part.range(c.rank());
            a.spmv(&c, &xg[s..e]).await
        }
    });
    let got: Vec<f64> = out.results.into_iter().flatten().collect();
    for (i, (g, e)) in got.iter().zip(&y_expect).enumerate() {
        assert!((g - e).abs() < 1e-12, "row {i}: {g} vs {e}");
    }
}

/// Steady-state red dots: the locality-aware persistent engine sends
/// strictly fewer inter-node messages per iteration than either direct
/// engine (which agree with each other).
#[test]
fn steady_state_locality_reduces_internode_messages() {
    let preset = Rc::new(MatrixPreset::cage14_like().scaled(200));
    let topo = Topology::quartz(4, 4);
    let run = |method| {
        run_halo_once(
            topo.clone(),
            MpiFlavor::Mvapich2,
            SddeAlgorithm::NonBlocking,
            RegionKind::Node,
            method,
            4,
            preset.clone(),
            9,
        )
    };
    let (setup_p2p, _, p2p_sent) = run(HaloMethod::P2p);
    let (_, _, std_sent) = run(HaloMethod::Persistent);
    let (setup_loc, _, loc_sent) = run(HaloMethod::LocalityPersistent);
    assert_eq!(setup_p2p, 0, "legacy path must have no setup phase");
    assert!(setup_loc > 0, "locality plan negotiation is not free");
    assert_eq!(p2p_sent, std_sent, "direct engines send identical counts");
    assert!(
        loc_sent < std_sent,
        "aggregated {loc_sent} not below direct {std_sent}"
    );
}
