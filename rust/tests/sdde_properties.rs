//! Property-based tests over randomized topologies and patterns, checking
//! the DESIGN.md invariants:
//! 1. agreement with the sequential oracle (all algorithms),
//! 2. duality (recv pattern == transpose of send pattern),
//! 3. conservation (Σ sent == Σ received, payloads intact),
//! 4. determinism (same seed → identical virtual times and counters).

use std::collections::BTreeMap;
use std::rc::Rc;

use sdde::mpi::World;
use sdde::mpix::{
    alltoallv_crs, CrsvArgs, CrsvResult, IntraAlgo, MpixComm, MpixInfo, SddeAlgorithm,
};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::util::{prop, Rng};

fn random_topology(rng: &mut Rng) -> Topology {
    let nodes = 1 + rng.usize_below(5);
    let ppn = 1 + rng.usize_below(8);
    Topology::quartz(nodes, ppn)
}

fn random_pattern(rng: &mut Rng, n: usize) -> Vec<CrsvArgs> {
    (0..n)
        .map(|p| {
            let deg = rng.usize_below(n);
            let dest = rng.sample_distinct(n, deg);
            let sendcounts: Vec<usize> = dest.iter().map(|_| 1 + rng.usize_below(5)).collect();
            let mut sendvals = Vec::new();
            for (i, &d) in dest.iter().enumerate() {
                for k in 0..sendcounts[i] {
                    sendvals.push((p * 100_000 + d * 100 + k) as u64);
                }
            }
            CrsvArgs {
                dest,
                sendcounts,
                sendvals,
            }
        })
        .collect()
}

fn oracle(pattern: &[CrsvArgs]) -> Vec<CrsvResult> {
    let n = pattern.len();
    let mut recv: Vec<BTreeMap<usize, Vec<u64>>> = vec![BTreeMap::new(); n];
    for (p, args) in pattern.iter().enumerate() {
        for (i, &d) in args.dest.iter().enumerate() {
            recv[d].insert(p, args.vals(i).to_vec());
        }
    }
    recv.into_iter()
        .map(|m| CrsvResult::from_pairs(m.into_iter().collect()))
        .collect()
}

fn run(
    topo: &Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    intra: IntraAlgo,
    pattern: &[CrsvArgs],
) -> (Vec<CrsvResult>, u64) {
    let world = World::new(topo.clone(), CostModel::preset(flavor));
    let pats = Rc::new(pattern.to_vec());
    let out = world.run(move |c| {
        let pats = pats.clone();
        async move {
            let mx = MpixComm::new(c.clone(), region);
            let info = MpixInfo {
                algorithm: algo,
                region,
                intra,
                ..MpixInfo::default()
            };
            alltoallv_crs(&mx, &info, &pats[c.rank()]).await.unwrap()
        }
    });
    (out.results, out.end_time)
}

#[test]
fn prop_agreement_all_algorithms_random_worlds() {
    prop::check(30, |rng| {
        let topo = random_topology(rng);
        let pattern = random_pattern(rng, topo.nranks());
        let expect = oracle(&pattern);
        let region = if rng.chance(0.5) {
            RegionKind::Node
        } else {
            RegionKind::Socket
        };
        let intra = if rng.chance(0.5) {
            IntraAlgo::Personalized
        } else {
            IntraAlgo::Alltoallv
        };
        let flavor = if rng.chance(0.5) {
            MpiFlavor::Mvapich2
        } else {
            MpiFlavor::OpenMpi
        };
        for algo in SddeAlgorithm::VARIABLE {
            let (got, _) = run(&topo, flavor, algo, region, intra, &pattern);
            if got != expect {
                return Err(format!(
                    "{algo:?}/{region:?}/{intra:?} disagreed with oracle on {}x{}",
                    topo.nodes, topo.ppn
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_duality_and_conservation() {
    prop::check(30, |rng| {
        let topo = random_topology(rng);
        let n = topo.nranks();
        let pattern = random_pattern(rng, n);
        let (results, _) = run(
            &topo,
            MpiFlavor::Mvapich2,
            SddeAlgorithm::LocalityNonBlocking,
            RegionKind::Node,
            IntraAlgo::Personalized,
            &pattern,
        );
        // duality: rank d received exactly what rank p addressed to d
        for (p, args) in pattern.iter().enumerate() {
            for (i, &d) in args.dest.iter().enumerate() {
                let r = &results[d];
                let Some(j) = r.src.iter().position(|&s| s == p) else {
                    return Err(format!("rank {d} missing message from {p}"));
                };
                if r.vals(j) != args.vals(i) {
                    return Err(format!("payload {p}->{d} corrupted"));
                }
            }
        }
        // conservation: total words sent == total words received
        let sent: usize = pattern.iter().map(|a| a.sendvals.len()).sum();
        let recvd: usize = results.iter().map(|r| r.recv_size()).sum();
        if sent != recvd {
            return Err(format!("sent {sent} != received {recvd}"));
        }
        // no phantom sources
        for (d, r) in results.iter().enumerate() {
            for (j, &s) in r.src.iter().enumerate() {
                let args = &pattern[s];
                let Some(i) = args.dest.iter().position(|&x| x == d) else {
                    return Err(format!("rank {d} got phantom message from {s}"));
                };
                if args.vals(i) != r.vals(j) {
                    return Err(format!("phantom payload {s}->{d}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_determinism() {
    prop::check(10, |rng| {
        let topo = random_topology(rng);
        let pattern = random_pattern(rng, topo.nranks());
        for algo in [
            SddeAlgorithm::Personalized,
            SddeAlgorithm::NonBlocking,
            SddeAlgorithm::LocalityNonBlocking,
        ] {
            let (r1, t1) = run(
                &topo,
                MpiFlavor::OpenMpi,
                algo,
                RegionKind::Node,
                IntraAlgo::Personalized,
                &pattern,
            );
            let (r2, t2) = run(
                &topo,
                MpiFlavor::OpenMpi,
                algo,
                RegionKind::Node,
                IntraAlgo::Personalized,
                &pattern,
            );
            if t1 != t2 {
                return Err(format!("{algo:?}: virtual time {t1} != {t2}"));
            }
            if r1 != r2 {
                return Err(format!("{algo:?}: results differ between identical runs"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_invalid_args_rejected() {
    // API-contract checks under random inputs: duplicate destinations and
    // count mismatches must be rejected, not silently mangled.
    prop::check(20, |rng| {
        let n = 4 + rng.usize_below(8);
        let d = rng.usize_below(n);
        let bad = CrsvArgs {
            dest: vec![d, d],
            sendcounts: vec![1, 1],
            sendvals: vec![1, 2],
        };
        if bad.validate().is_ok() {
            return Err("duplicate destination accepted".into());
        }
        let bad2 = CrsvArgs {
            dest: vec![d],
            sendcounts: vec![3],
            sendvals: vec![1],
        };
        if bad2.validate().is_ok() {
            return Err("count mismatch accepted".into());
        }
        Ok(())
    });
}

#[test]
fn prop_locality_reduces_or_preserves_internode_count() {
    // Structural invariant of aggregation: max inter-node user messages of
    // the locality-aware algorithm never exceed standard + region bound.
    prop::check(15, |rng| {
        let nodes = 2 + rng.usize_below(4);
        let topo = Topology::quartz(nodes, 2 + rng.usize_below(6));
        let n = topo.nranks();
        let pattern = random_pattern(rng, n);
        let count = |algo| {
            let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
            let pats = Rc::new(pattern.clone());
            let out = world.run(move |c| {
                let pats = pats.clone();
                async move {
                    let mx = MpixComm::new(c.clone(), RegionKind::Node);
                    let info = MpixInfo::with_algorithm(algo);
                    alltoallv_crs(&mx, &info, &pats[c.rank()]).await.unwrap();
                }
            });
            out.counters.max_internode_per_rank()
        };
        let agg = count(SddeAlgorithm::LocalityNonBlocking);
        // aggregated inter-node sends per rank are bounded by nodes-1 per
        // phase; intra-phase sends are never inter-node
        if agg > (nodes as u64 - 1) {
            return Err(format!(
                "aggregated inter-node count {agg} exceeds nodes-1={}",
                nodes - 1
            ));
        }
        Ok(())
    });
}
