//! Multi-pattern worlds under chaos: K concurrent SDDEs in ONE world,
//! each on its own derived communicator (nested dup chain), must behave
//! exactly like K serial single-pattern runs — under every algorithm,
//! on several topologies, and under seeded fault plans with duplicate
//! delivery and deep unexpected queues. The per-context trace rollup is
//! the evidence: send↔recv conservation holds *per context* and the
//! cross-context delivery audit stays at zero. The one deliberately
//! broken member of the suite — a half-migrated program whose receiver
//! still posts on the un-split world — must hang and be diagnosed by a
//! `WaitGraph` near-miss naming the context mismatch.

use sdde::bench::{oracle_digests, run_multi, MultiConfig, Variant};
use sdde::mpi::{CtxId, MissReason, Payload, World};
use sdde::mpix::SddeAlgorithm;
use sdde::simnet::{CostModel, FaultPlan, FaultProfile, MpiFlavor, Topology};
use sdde::sparse::MatrixPreset;

fn cfg(topo: Topology, k: usize, algo: SddeAlgorithm, variant: Variant) -> MultiConfig {
    MultiConfig::new(
        topo,
        MpiFlavor::Mvapich2,
        k,
        MatrixPreset::cage14_like().scaled(400),
    )
    .algo(algo)
    .variant(variant)
    .watchdog(None)
}

/// Every algorithm, two topologies: K=2 concurrent SDDEs return exactly
/// what each pattern returns when run alone, and the world's trace shows
/// zero cross-context deliveries with per-context conservation intact.
#[test]
fn concurrent_patterns_match_serial_oracles_all_algorithms() {
    for (nodes, ppn) in [(2, 2), (2, 4)] {
        for algo in SddeAlgorithm::ALL {
            // RMA exists only for the constant-size API (paper §IV-C).
            let variant = if algo == SddeAlgorithm::Rma {
                Variant::ConstSize
            } else {
                Variant::Variable
            };
            let c = cfg(Topology::quartz(nodes, ppn), 2, algo, variant);
            let run = run_multi(&c);
            let label = format!("{} on {}x{}", algo.name(), nodes, ppn);
            assert_eq!(run.digests, oracle_digests(&c), "{label}");
            let s = &run.trace.summary;
            assert_eq!(s.cross_ctx_matches, 0, "{label}");
            assert!(s.has_multiple_ctx(), "{label}");
            assert!(s.conservation_ok(), "{label}");
        }
    }
}

/// Per-context conservation survives seeded chaos: both fault presets
/// that stress matching the hardest (heavy = jitter + stragglers +
/// forced rendezvous + duplicates; duplicate = duplicate-delivery only),
/// four seeds each. Faults may move virtual time, never messages — so
/// the digests must still match the fault-free serial oracles.
#[test]
fn per_context_conservation_under_faults() {
    for profile in ["heavy", "duplicate"] {
        let base = cfg(
            Topology::quartz(2, 2),
            2,
            SddeAlgorithm::NonBlocking,
            Variant::Variable,
        );
        let oracle = oracle_digests(&base);
        for seed in 1..=4u64 {
            let plan = FaultPlan::with_profile(seed, FaultProfile::parse(profile).unwrap());
            let run = run_multi(&base.clone().faults(Some(plan)));
            let s = &run.trace.summary;
            assert_eq!(s.cross_ctx_matches, 0, "{profile} seed {seed}");
            assert!(s.has_multiple_ctx(), "{profile} seed {seed}");
            assert!(s.conservation_ok(), "{profile} seed {seed}");
            assert_eq!(run.digests, oracle, "{profile} seed {seed}");
        }
    }
}

/// The acceptance bar: K=4 concurrent SDDEs under heavy faults keep all
/// four contexts conserved with zero cross-context matches, and every
/// pattern still agrees with its serial oracle.
#[test]
fn four_patterns_under_heavy_faults_stay_isolated() {
    let c = cfg(
        Topology::quartz(2, 4),
        4,
        SddeAlgorithm::Dispatch,
        Variant::Variable,
    )
    .faults(Some(FaultPlan::with_profile(42, FaultProfile::heavy())));
    let run = run_multi(&c);
    let s = &run.trace.summary;
    assert_eq!(
        s.by_ctx.keys().filter(|&&k| k != 0).count(),
        4,
        "each pattern's communicator must carry traffic"
    );
    assert_eq!(s.cross_ctx_matches, 0);
    assert!(s.conservation_ok());
    assert_eq!(run.digests, oracle_digests(&c));
}

/// The failure mode contexts exist to prevent, reproduced on purpose: a
/// half-migrated program where the sender moved to a derived
/// communicator but the receiver still posts on the un-split world.
/// Right (src, tag), wrong context — the receive can never match, and
/// the wait-graph diagnosis must say exactly that.
#[test]
fn unsplit_receiver_reproduces_cross_talk_hang() {
    let err = World::new(
        Topology::quartz(1, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
    )
    .run_checked(|c| async move {
        let sub = c.dup().await;
        if c.rank() == 0 {
            sub.send(1, 0x1000, Payload::ints(&[1])).await;
        } else {
            let _ = c.recv(0, 0x1000).await; // un-migrated: world context
        }
    })
    .expect_err("cross-context traffic must stall");
    assert_eq!(err.blocked_ranks(), vec![1]);
    let nm = &err.blocked[0].near_misses;
    assert_eq!(nm.len(), 1);
    assert_eq!((nm[0].src, nm[0].tag), (0, 0x1000));
    assert_eq!(nm[0].reason, MissReason::CtxMismatch);
    assert_eq!(nm[0].ctx, CtxId(1));
    assert_eq!(nm[0].wanted_ctx, CtxId::WORLD);
    let text = err.render();
    assert!(text.contains("context mismatch"), "{text}");
}
