//! Hang-diagnosis corpus: classic MPI deadlock/livelock bugs, each run
//! through `World::run_checked`, which must *terminate* (no wall-clock
//! timeouts) and hand back a `WaitGraph` naming the blocked operations,
//! the envelopes they wait for, near-miss unexpected messages, and any
//! wait-for cycle. The out-of-start-order locality wait — the one corpus
//! member diagnosed at the API layer before a hang can form — fail-fasts
//! with a panic instead (see also tests/neighbor_agreement.rs).

use sdde::mpi::{MissReason, OpKind, Payload, World};
use sdde::simnet::{CostModel, MpiFlavor, Stall, Time, Topology};

fn world(nodes: usize, ppn: usize) -> World {
    World::new(Topology::quartz(nodes, ppn), CostModel::preset(MpiFlavor::Mvapich2))
}

/// Mismatched tag: the sender uses tag 7, the receiver waits on tag 8.
/// The diagnostic must point at the near-miss (same source, wrong tag)
/// sitting in the receiver's unexpected queue.
#[test]
fn mismatched_tag_is_reported_as_near_miss() {
    let err = world(1, 2)
        .run_checked(|c| async move {
            match c.rank() {
                0 => {
                    c.send(1, 7, Payload::ints(&[1, 2, 3])).await;
                }
                _ => {
                    let _ = c.recv(0, 8).await; // typo'd tag: hangs forever
                }
            }
        })
        .expect_err("mismatched tags must stall");
    assert!(matches!(err.stall, Stall::Deadlock { .. }));
    assert_eq!(err.blocked_ranks(), vec![1]);
    let ops = err.ops_of(1);
    assert_eq!(ops.len(), 1);
    assert_eq!(ops[0].kind, OpKind::Recv);
    assert_eq!((ops[0].peer, ops[0].tag), (0, 8));
    let nm = &err.blocked[0].near_misses;
    assert_eq!(nm.len(), 1);
    assert_eq!((nm[0].src, nm[0].tag), (0, 7));
    assert_eq!(nm[0].reason, MissReason::TagMismatch);
    assert!(err.cycle.is_none());
    let text = err.render();
    assert!(text.contains("near miss"), "{text}");
    assert!(text.contains("tag mismatch"), "{text}");
}

/// Missing receive: a synchronous send whose receiver exits without ever
/// posting. The diagnostic names the blocked sync-send and the envelope
/// it still hopes someone will match.
#[test]
fn missing_recv_reports_blocked_sync_send() {
    let err = world(1, 2)
        .run_checked(|c| async move {
            if c.rank() == 0 {
                let r = c.issend(1, 5, Payload::ints(&[9])).await;
                r.await; // completes only on match — never
            }
            // rank 1 exits immediately: the classic forgotten recv.
        })
        .expect_err("sync send without a receiver must stall");
    assert!(matches!(err.stall, Stall::Deadlock { .. }));
    assert_eq!(err.blocked_ranks(), vec![0]);
    let ops = err.ops_of(0);
    assert_eq!(ops.len(), 1);
    assert_eq!(ops[0].kind, OpKind::SyncSend);
    assert_eq!((ops[0].peer, ops[0].tag), (1, 5));
    assert!(ops[0].since.is_some(), "registry ops carry a start time");
    // One-sided blocking is not a cycle.
    assert!(err.cycle.is_none());
    assert!(err.render().contains("no wait cycle"), "{}", err.render());
}

/// Send/send deadlock: both ranks push a rendezvous-sized message and
/// wait for completion before receiving. The wait graph must close the
/// 0 -> 1 -> 0 cycle.
#[test]
fn rendezvous_send_send_cycle_is_detected() {
    let err = world(1, 2)
        .run_checked(|c| async move {
            let me = c.rank();
            let peer = 1 - me;
            // 80 KB: far above both presets' eager limits, so the send
            // blocks until the (never-posted) receive matches.
            let r = c.isend(peer, 3, Payload::longs(&vec![me as u64; 10_000])).await;
            r.await;
            let _ = c.recv(peer, 3).await; // never reached
        })
        .expect_err("head-on rendezvous sends must stall");
    assert_eq!(err.blocked_ranks(), vec![0, 1]);
    for rank in [0, 1] {
        let ops = err.ops_of(rank);
        assert_eq!(ops.len(), 1, "rank {rank}");
        assert_eq!(ops[0].kind, OpKind::RendezvousSend, "rank {rank}");
        assert_eq!(ops[0].peer, 1 - rank, "rank {rank}");
    }
    let cycle = err.cycle.clone().expect("cycle must be found");
    assert_eq!(cycle.first(), cycle.last(), "closed path");
    assert!(cycle.contains(&0) && cycle.contains(&1), "{cycle:?}");
    assert!(err.render().contains("cycle: "), "{}", err.render());
}

/// Blocking probe with no sender: the RAII op registry must surface the
/// probe's envelope in the report.
#[test]
fn blocked_probe_is_reported() {
    let err = world(1, 2)
        .run_checked(|c| async move {
            if c.rank() == 1 {
                let _ = c.probe(0, 12).await; // nothing ever arrives
            }
        })
        .expect_err("probe without a sender must stall");
    assert_eq!(err.blocked_ranks(), vec![1]);
    let ops = err.ops_of(1);
    assert_eq!(ops.len(), 1);
    assert_eq!(ops[0].kind, OpKind::Probe);
    assert_eq!((ops[0].peer, ops[0].tag), (0, 12));
}

/// Livelock, not deadlock: one rank spins on the CPU forever while
/// another waits on it. The timer heap never drains, so only the
/// virtual-time quiescence watchdog can catch this — it must trip at the
/// horizon and still name the blocked sync-send.
#[test]
fn watchdog_catches_busy_spin_livelock() {
    const HORIZON: Time = 1_000_000; // 1 ms of virtual silence
    let err = World::builder(
        Topology::quartz(1, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
    )
    .watchdog(HORIZON)
    .build()
    .run_checked(|c| async move {
        if c.rank() == 0 {
            let r = c.issend(1, 4, Payload::ints(&[7])).await;
            r.await;
        } else {
            // Polls "is it done yet?" without ever receiving: virtual
            // time advances forever, progress never happens. Bounded
            // only so a watchdog regression fails fast instead of
            // running the loop out.
            for _ in 0..1_000_000 {
                c.charge_cpu(1_000).await;
            }
        }
    })
    .expect_err("watchdog must declare quiescence");
    assert!(
        matches!(err.stall, Stall::Quiescent { .. }),
        "expected quiescence, got {:?}",
        err.stall
    );
    let ops = err.ops_of(0);
    assert_eq!(ops.len(), 1);
    assert_eq!(ops[0].kind, OpKind::SyncSend);
    let text = err.render();
    assert!(text.contains("quiescent (watchdog)"), "{text}");
    assert!(text.contains("last progress"), "{text}");
}

/// A healthy program through `run_checked` is not disturbed: same results
/// as `run`, no diagnostic.
#[test]
fn run_checked_passes_healthy_programs_through() {
    let out = world(1, 2)
        .run_checked(|c| async move {
            let me = c.rank();
            let peer = 1 - me;
            let r = c.isend(peer, 1, Payload::ints(&[me as u64])).await;
            let m = c.recv(peer, 1).await;
            r.await;
            m.payload.words[0]
        })
        .expect("healthy program must not stall");
    assert_eq!(out.results, vec![1, 0]);
}
