//! Integration tests: every SDDE algorithm must produce the exact result a
//! sequential oracle computes from the global pattern (paper invariant 1 in
//! DESIGN.md), across topologies, region kinds and pattern densities.
//!
//! The big (algorithm × topology) matrices run their cells on worker
//! threads via `bench::par::run_cells` (`SDDE_JOBS=N` to parallelize);
//! each cell builds its own single-threaded `World`, and results are
//! jobs-invariant, so only wall-clock changes.

use std::collections::BTreeMap;
use std::rc::Rc;

use sdde::bench::{resolve_jobs, run_cells, ProgressSink};
use sdde::mpi::World;
use sdde::mpix::{
    alltoall_crs, alltoallv_crs, CrsArgs, CrsResult, CrsvArgs, CrsvResult, IntraAlgo, MpixComm,
    MpixInfo, SddeAlgorithm,
};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::util::Rng;

/// Random sparse send pattern: for each rank, a sorted set of distinct
/// destinations with variable-length value lists.
fn random_pattern(nranks: usize, max_deg: usize, max_len: usize, seed: u64) -> Vec<CrsvArgs> {
    let mut rng = Rng::new(seed);
    (0..nranks)
        .map(|p| {
            let deg = rng.usize_below(max_deg.min(nranks) + 1);
            let dest = rng.sample_distinct(nranks, deg);
            let sendcounts: Vec<usize> = dest.iter().map(|_| 1 + rng.usize_below(max_len)).collect();
            let mut sendvals = Vec::new();
            for (i, &d) in dest.iter().enumerate() {
                for k in 0..sendcounts[i] {
                    sendvals.push((p * 1_000_000 + d * 1_000 + k) as u64);
                }
            }
            CrsvArgs {
                dest,
                sendcounts,
                sendvals,
            }
        })
        .collect()
}

/// Sequential oracle: transpose the global send pattern.
fn oracle_v(pattern: &[CrsvArgs]) -> Vec<CrsvResult> {
    let n = pattern.len();
    let mut recv: Vec<BTreeMap<usize, Vec<u64>>> = vec![BTreeMap::new(); n];
    for (p, args) in pattern.iter().enumerate() {
        for (i, &d) in args.dest.iter().enumerate() {
            recv[d].insert(p, args.vals(i).to_vec());
        }
    }
    recv.into_iter()
        .map(|m| CrsvResult::from_pairs(m.into_iter().collect()))
        .collect()
}

fn run_v(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    intra: IntraAlgo,
    pattern: Vec<CrsvArgs>,
) -> Vec<CrsvResult> {
    let pattern = Rc::new(pattern);
    let world = World::new(topo, CostModel::preset(flavor));
    let out = world.run(move |c| {
        let pattern = pattern.clone();
        async move {
            let mx = MpixComm::new(c.clone(), region);
            let info = MpixInfo {
                algorithm: algo,
                region,
                intra,
                ..MpixInfo::default()
            };
            alltoallv_crs(&mx, &info, &pattern[c.rank()]).await.unwrap()
        }
    });
    out.results
}

/// One (topology, algorithm, seed) oracle check; `None` on agreement,
/// `Some(description)` on the first mismatch. Worker-safe: panics stay
/// out of the worker threads, the calling test asserts on the collected
/// reports.
fn check_algo_v_report(topo: Topology, algo: SddeAlgorithm, seed: u64) -> Option<String> {
    let n = topo.nranks();
    let pattern = random_pattern(n, n / 2 + 2, 6, seed);
    let expect = oracle_v(&pattern);
    for flavor in [MpiFlavor::Mvapich2, MpiFlavor::OpenMpi] {
        let got = run_v(
            topo.clone(),
            flavor,
            algo,
            RegionKind::Node,
            IntraAlgo::Personalized,
            pattern.clone(),
        );
        if got != expect {
            return Some(format!(
                "algo={algo:?} flavor={flavor:?} seed={seed}: result != oracle"
            ));
        }
    }
    None
}

fn check_algo_v(topo: Topology, algo: SddeAlgorithm, seed: u64) {
    if let Some(m) = check_algo_v_report(topo, algo, seed) {
        panic!("{m}");
    }
}

#[test]
fn variable_matrix_all_algorithms_match_oracle() {
    // The full variable-size (algorithm × topology) matrix, one parallel
    // cell per combination.
    let cells: Vec<(usize, usize, SddeAlgorithm, u64)> = vec![
        (2, 4, SddeAlgorithm::Personalized, 1),
        (4, 8, SddeAlgorithm::Personalized, 2),
        (2, 4, SddeAlgorithm::NonBlocking, 3),
        (4, 8, SddeAlgorithm::NonBlocking, 4),
        (2, 4, SddeAlgorithm::LocalityPersonalized, 5),
        (4, 8, SddeAlgorithm::LocalityPersonalized, 6),
        (3, 5, SddeAlgorithm::LocalityPersonalized, 7),
        (2, 4, SddeAlgorithm::LocalityNonBlocking, 8),
        (4, 8, SddeAlgorithm::LocalityNonBlocking, 9),
        (3, 5, SddeAlgorithm::LocalityNonBlocking, 10),
    ];
    let (reports, _) = run_cells(
        resolve_jobs(None),
        cells.len(),
        ProgressSink::Silent,
        |i, _| {
            let (nodes, ppn, algo, seed) = cells[i];
            check_algo_v_report(Topology::quartz(nodes, ppn), algo, seed)
        },
    );
    let failures: Vec<String> = reports.into_iter().flatten().collect();
    assert!(failures.is_empty(), "{failures:#?}");
}

#[test]
fn locality_socket_regions_match_oracle() {
    let topo = Topology::quartz(2, 8);
    let pattern = random_pattern(topo.nranks(), 6, 4, 11);
    let expect = oracle_v(&pattern);
    for algo in [
        SddeAlgorithm::LocalityPersonalized,
        SddeAlgorithm::LocalityNonBlocking,
    ] {
        let got = run_v(
            topo.clone(),
            MpiFlavor::Mvapich2,
            algo,
            RegionKind::Socket,
            IntraAlgo::Personalized,
            pattern.clone(),
        );
        assert_eq!(got, expect, "algo={algo:?} socket regions");
    }
}

#[test]
fn locality_alltoallv_intra_matches_oracle() {
    let topo = Topology::quartz(2, 6);
    let pattern = random_pattern(topo.nranks(), 8, 4, 12);
    let expect = oracle_v(&pattern);
    for algo in [
        SddeAlgorithm::LocalityPersonalized,
        SddeAlgorithm::LocalityNonBlocking,
    ] {
        let got = run_v(
            topo.clone(),
            MpiFlavor::Mvapich2,
            algo,
            RegionKind::Node,
            IntraAlgo::Alltoallv,
            pattern.clone(),
        );
        assert_eq!(got, expect, "algo={algo:?} intra=alltoallv");
    }
}

#[test]
fn empty_pattern_all_algorithms() {
    let topo = Topology::quartz(2, 3);
    let pattern: Vec<CrsvArgs> = (0..topo.nranks()).map(|_| CrsvArgs::default()).collect();
    let expect = oracle_v(&pattern);
    for algo in SddeAlgorithm::VARIABLE {
        let got = run_v(
            topo.clone(),
            MpiFlavor::OpenMpi,
            algo,
            RegionKind::Node,
            IntraAlgo::Personalized,
            pattern.clone(),
        );
        assert_eq!(got, expect, "algo={algo:?} empty");
    }
}

#[test]
fn dense_pattern_all_algorithms() {
    // Everyone sends to everyone — stresses queue matching and aggregation.
    let topo = Topology::quartz(2, 4);
    let n = topo.nranks();
    let pattern: Vec<CrsvArgs> = (0..n)
        .map(|p| CrsvArgs {
            dest: (0..n).collect(),
            sendcounts: vec![2; n],
            sendvals: (0..n).flat_map(|d| vec![(p * 100 + d) as u64, 7]).collect(),
        })
        .collect();
    let expect = oracle_v(&pattern);
    for algo in SddeAlgorithm::VARIABLE {
        let got = run_v(
            topo.clone(),
            MpiFlavor::Mvapich2,
            algo,
            RegionKind::Node,
            IntraAlgo::Personalized,
            pattern.clone(),
        );
        assert_eq!(got, expect, "algo={algo:?} dense");
    }
}

#[test]
fn known_recv_nnz_skips_allreduce() {
    let topo = Topology::quartz(2, 4);
    let n = topo.nranks();
    let pattern = random_pattern(n, 4, 3, 13);
    let expect = oracle_v(&pattern);
    let recv_nnz: Vec<usize> = expect.iter().map(|r| r.recv_nnz()).collect();
    let pattern = Rc::new(pattern);
    let recv_nnz = Rc::new(recv_nnz);
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let out = world.run(move |c| {
        let pattern = pattern.clone();
        let recv_nnz = recv_nnz.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo {
                algorithm: SddeAlgorithm::Personalized,
                known_recv_nnz: Some(recv_nnz[c.rank()]),
                ..MpixInfo::default()
            };
            alltoallv_crs(&mx, &info, &pattern[c.rank()]).await.unwrap()
        }
    });
    assert_eq!(out.results, expect);
    assert_eq!(out.counters.allreduces, 0, "allreduce should be skipped");
}

// ---------------------------------------------------------------------------
// Constant-size API (MPIX_Alltoall_crs) — including RMA.
// ---------------------------------------------------------------------------

fn random_const_pattern(nranks: usize, max_deg: usize, sendcount: usize, seed: u64) -> Vec<CrsArgs> {
    let mut rng = Rng::new(seed);
    (0..nranks)
        .map(|p| {
            let deg = rng.usize_below(max_deg.min(nranks) + 1);
            let dest = rng.sample_distinct(nranks, deg);
            let sendvals = dest
                .iter()
                .flat_map(|&d| (0..sendcount).map(move |k| (p * 1000 + d * 10 + k) as u64))
                .collect();
            CrsArgs {
                dest,
                sendcount,
                sendvals,
            }
        })
        .collect()
}

fn oracle_c(pattern: &[CrsArgs], sendcount: usize) -> Vec<CrsResult> {
    let n = pattern.len();
    let mut recv: Vec<BTreeMap<usize, Vec<u64>>> = vec![BTreeMap::new(); n];
    for (p, args) in pattern.iter().enumerate() {
        for (i, &d) in args.dest.iter().enumerate() {
            recv[d].insert(p, args.vals(i).to_vec());
        }
    }
    recv.into_iter()
        .map(|m| {
            let mut res = CrsResult::default();
            for (s, v) in m {
                res.src.push(s);
                res.recvvals.extend(v);
            }
            debug_assert_eq!(res.recvvals.len(), res.src.len() * sendcount);
            res
        })
        .collect()
}

fn check_algo_c_report(
    topo: Topology,
    algo: SddeAlgorithm,
    sendcount: usize,
    seed: u64,
) -> Option<String> {
    let n = topo.nranks();
    let pattern = random_const_pattern(n, n / 2 + 2, sendcount, seed);
    let expect = oracle_c(&pattern, sendcount);
    let pattern = Rc::new(pattern);
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let out = world.run(move |c| {
        let pattern = pattern.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(algo);
            alltoall_crs(&mx, &info, &pattern[c.rank()]).await.unwrap()
        }
    });
    if out.results != expect {
        return Some(format!("algo={algo:?} seed={seed}: result != oracle"));
    }
    None
}

#[test]
fn alltoall_crs_all_algorithms_match_oracle() {
    // CONST_SIZE = the paper's five plus the locality-RMA extension (§VI);
    // two topologies per algorithm, one parallel cell per combination.
    let cells: Vec<(usize, usize, SddeAlgorithm, usize, u64)> = SddeAlgorithm::CONST_SIZE
        .into_iter()
        .enumerate()
        .flat_map(|(i, algo)| {
            [
                (2, 4, algo, 1, 20 + i as u64),
                (4, 4, algo, 3, 40 + i as u64),
            ]
        })
        .collect();
    let (reports, _) = run_cells(
        resolve_jobs(None),
        cells.len(),
        ProgressSink::Silent,
        |i, _| {
            let (nodes, ppn, algo, sendcount, seed) = cells[i];
            check_algo_c_report(Topology::quartz(nodes, ppn), algo, sendcount, seed)
        },
    );
    let failures: Vec<String> = reports.into_iter().flatten().collect();
    assert!(failures.is_empty(), "{failures:#?}");
}

#[test]
fn locality_rma_uneven_regions_and_reuse() {
    // Wrap-around corresponding ranks + window reuse across calls.
    let topo = Topology::quartz(3, 5);
    let n = topo.nranks();
    let p1 = random_const_pattern(n, 6, 2, 90);
    let p2 = random_const_pattern(n, 6, 2, 91);
    let e1 = oracle_c(&p1, 2);
    let e2 = oracle_c(&p2, 2);
    let p1 = Rc::new(p1);
    let p2 = Rc::new(p2);
    let world = World::new(topo, CostModel::preset(MpiFlavor::OpenMpi));
    let out = world.run(move |c| {
        let p1 = p1.clone();
        let p2 = p2.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityRma);
            let r1 = alltoall_crs(&mx, &info, &p1[c.rank()]).await.unwrap();
            let r2 = alltoall_crs(&mx, &info, &p2[c.rank()]).await.unwrap();
            (r1, r2)
        }
    });
    for (rank, (r1, r2)) in out.results.into_iter().enumerate() {
        assert_eq!(r1, e1[rank], "rank {rank} call 1");
        assert_eq!(r2, e2[rank], "rank {rank} call 2");
    }
}

#[test]
fn locality_rma_rejected_for_variable() {
    let world = World::new(
        Topology::quartz(1, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
    );
    let out = world.run(|c| async move {
        let mx = MpixComm::new(c.clone(), RegionKind::Node);
        let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityRma);
        alltoallv_crs(&mx, &info, &CrsvArgs::default()).await.is_err()
    });
    assert!(out.results.iter().all(|&e| e));
}

#[test]
fn rma_window_reuse_across_calls() {
    let topo = Topology::quartz(2, 2);
    let n = topo.nranks();
    let p1 = random_const_pattern(n, 3, 1, 50);
    let p2 = random_const_pattern(n, 3, 1, 51);
    let e1 = oracle_c(&p1, 1);
    let e2 = oracle_c(&p2, 1);
    let p1 = Rc::new(p1);
    let p2 = Rc::new(p2);
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let out = world.run(move |c| {
        let p1 = p1.clone();
        let p2 = p2.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(SddeAlgorithm::Rma);
            let r1 = alltoall_crs(&mx, &info, &p1[c.rank()]).await.unwrap();
            let r2 = alltoall_crs(&mx, &info, &p2[c.rank()]).await.unwrap();
            (r1, r2)
        }
    });
    for (rank, (r1, r2)) in out.results.into_iter().enumerate() {
        assert_eq!(r1, e1[rank]);
        assert_eq!(r2, e2[rank], "stale window state leaked into call 2");
    }
}

#[test]
fn rma_rejected_for_variable() {
    let world = World::new(
        Topology::quartz(1, 2),
        CostModel::preset(MpiFlavor::Mvapich2),
    );
    let out = world.run(|c| async move {
        let mx = MpixComm::new(c.clone(), RegionKind::Node);
        let info = MpixInfo::with_algorithm(SddeAlgorithm::Rma);
        alltoallv_crs(&mx, &info, &CrsvArgs::default()).await.is_err()
    });
    assert!(out.results.iter().all(|&e| e));
}

#[test]
fn dispatch_resolves_and_matches_oracle() {
    check_algo_v(Topology::quartz(2, 4), SddeAlgorithm::Dispatch, 60);
}

#[test]
fn back_to_back_exchanges_do_not_crosstalk() {
    // Two SDDE calls in a row with different patterns; tags must isolate.
    let topo = Topology::quartz(2, 4);
    let n = topo.nranks();
    let pa = random_pattern(n, 4, 3, 70);
    let pb = random_pattern(n, 4, 3, 71);
    let ea = oracle_v(&pa);
    let eb = oracle_v(&pb);
    let pa = Rc::new(pa);
    let pb = Rc::new(pb);
    for algo in [SddeAlgorithm::NonBlocking, SddeAlgorithm::LocalityNonBlocking] {
        let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
        let pa = pa.clone();
        let pb = pb.clone();
        let out = world.run(move |c| {
            let pa = pa.clone();
            let pb = pb.clone();
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(algo);
                let ra = alltoallv_crs(&mx, &info, &pa[c.rank()]).await.unwrap();
                let rb = alltoallv_crs(&mx, &info, &pb[c.rank()]).await.unwrap();
                (ra, rb)
            }
        });
        for (rank, (ra, rb)) in out.results.into_iter().enumerate() {
            assert_eq!(ra, ea[rank], "algo={algo:?} first call");
            assert_eq!(rb, eb[rank], "algo={algo:?} second call");
        }
    }
}
