//! Perturbation-invariance proofs (paper invariant 8 in DESIGN.md): a
//! seeded fault plan — latency jitter, stragglers, forced rendezvous,
//! duplicate delivery — may move *virtual time*, but must never change
//! what any SDDE algorithm computes, what the solver stack computes, or
//! how many user messages cross the network; and `FaultPlan::off()` must
//! be bit-identical to a world with no fault layer at all.

use std::collections::BTreeMap;
use std::rc::Rc;

use sdde::bench::{
    resolve_jobs, run_cells, run_sweep, write_csv, FigureId, Point, ProgressSink, SweepConfig,
};
use sdde::mpi::World;
use sdde::mpix::{alltoall_crs, CrsArgs, CrsResult, MpixComm, MpixInfo, NeighborMethod,
    SddeAlgorithm};
use sdde::simnet::{CostModel, FaultPlan, FaultProfile, MpiFlavor, RegionKind, Topology};
use sdde::solver::DistMatrix;
use sdde::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};
use sdde::util::Rng;

fn random_const_pattern(nranks: usize, max_deg: usize, sendcount: usize, seed: u64) -> Vec<CrsArgs> {
    let mut rng = Rng::new(seed);
    (0..nranks)
        .map(|p| {
            let deg = rng.usize_below(max_deg.min(nranks) + 1);
            let dest = rng.sample_distinct(nranks, deg);
            let sendvals = dest
                .iter()
                .flat_map(|&d| (0..sendcount).map(move |k| (p * 1000 + d * 10 + k) as u64))
                .collect();
            CrsArgs {
                dest,
                sendcount,
                sendvals,
            }
        })
        .collect()
}

fn oracle_c(pattern: &[CrsArgs]) -> Vec<CrsResult> {
    let n = pattern.len();
    let mut recv: Vec<BTreeMap<usize, Vec<u64>>> = vec![BTreeMap::new(); n];
    for (p, args) in pattern.iter().enumerate() {
        for (i, &d) in args.dest.iter().enumerate() {
            recv[d].insert(p, args.vals(i).to_vec());
        }
    }
    recv.into_iter()
        .map(|m| {
            let mut res = CrsResult::default();
            for (s, v) in m {
                res.src.push(s);
                res.recvvals.extend(v);
            }
            res
        })
        .collect()
}

/// Run one const-size SDDE under an optional fault plan and return the
/// per-rank results plus total user messages (the traffic invariant).
fn run_c_faulted(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    pattern: Vec<CrsArgs>,
    faults: Option<FaultPlan>,
) -> (Vec<CrsResult>, u64) {
    let pattern = Rc::new(pattern);
    let world = World::builder(topo, CostModel::preset(flavor))
        .faults(faults)
        .build();
    let out = world.run(move |c| {
        let pattern = pattern.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(algo);
            alltoall_crs(&mx, &info, &pattern[c.rank()]).await.unwrap()
        }
    });
    let msgs = out.counters.total_user_msgs();
    (out.results, msgs)
}

/// Acceptance core: all five SDDE algorithms × both MPI presets reproduce
/// the sequential oracle under ≥ 8 seeded fault plans (heavy profile:
/// every perturbation class at once), with user-message counts identical
/// to the unfaulted run. One parallel cell per (algo, flavor).
#[test]
fn all_algorithms_match_oracle_under_eight_fault_seeds() {
    const SEEDS: [u64; 8] = [1, 2, 3, 5, 8, 13, 21, 42];
    let cells: Vec<(SddeAlgorithm, MpiFlavor)> = SddeAlgorithm::ALL
        .into_iter()
        .flat_map(|a| [(a, MpiFlavor::Mvapich2), (a, MpiFlavor::OpenMpi)])
        .collect();
    let (reports, _) = run_cells(
        resolve_jobs(None),
        cells.len(),
        ProgressSink::Silent,
        |i, _| {
            let (algo, flavor) = cells[i];
            let topo = Topology::quartz(2, 4);
            let pattern = random_const_pattern(topo.nranks(), 5, 2, 100 + i as u64);
            let expect = oracle_c(&pattern);
            let (base, base_msgs) =
                run_c_faulted(topo.clone(), flavor, algo, pattern.clone(), None);
            if base != expect {
                return Some(format!("{algo:?}/{flavor:?}: fault-free run != oracle"));
            }
            for seed in SEEDS {
                let plan = FaultPlan::with_profile(seed, FaultProfile::heavy());
                let (got, msgs) =
                    run_c_faulted(topo.clone(), flavor, algo, pattern.clone(), Some(plan));
                if got != expect {
                    return Some(format!("{algo:?}/{flavor:?} fault seed {seed}: != oracle"));
                }
                if msgs != base_msgs {
                    return Some(format!(
                        "{algo:?}/{flavor:?} fault seed {seed}: user msgs {msgs} != {base_msgs}"
                    ));
                }
            }
            None
        },
    );
    let failures: Vec<String> = reports.into_iter().flatten().collect();
    assert!(failures.is_empty(), "{failures:#?}");
}

/// Each perturbation class in isolation (jitter / straggler / forced
/// rendezvous / duplicate delivery) preserves the oracle result too —
/// localizes a regression to one fault mechanism.
#[test]
fn each_fault_class_alone_preserves_results() {
    let profiles = [
        ("jitter", FaultProfile::jitter()),
        ("straggler", FaultProfile::straggler()),
        ("rendezvous", FaultProfile::rendezvous()),
        ("duplicate", FaultProfile::duplicate()),
    ];
    let topo = Topology::quartz(3, 3);
    let pattern = random_const_pattern(topo.nranks(), 6, 3, 7);
    let expect = oracle_c(&pattern);
    for (name, profile) in profiles {
        for seed in [11, 12] {
            let plan = FaultPlan::with_profile(seed, profile);
            let (got, _) = run_c_faulted(
                topo.clone(),
                MpiFlavor::Mvapich2,
                SddeAlgorithm::LocalityNonBlocking,
                pattern.clone(),
                Some(plan),
            );
            assert_eq!(got, expect, "profile {name} seed {seed}");
        }
    }
}

/// Neighbor-persistent SpMV stays bit-for-bit identical to the legacy p2p
/// halo — and to its own fault-free run — under heavy perturbation
/// (acceptance: same arithmetic, different wires, perturbed timing).
#[test]
fn persistent_spmv_bitwise_stable_under_faults() {
    let preset = MatrixPreset::poisson2d(16, 12);
    let topo = Topology::quartz(2, 4);
    let part = Partition::new(preset.n, topo.nranks());

    let run = |faults: Option<FaultPlan>| -> Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let world = World::builder(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2))
            .faults(faults)
            .build();
        let preset2 = Rc::new(preset.clone());
        let out = world.run(move |c| {
            let preset = preset2.clone();
            async move {
                let rank = c.rank();
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityNonBlocking);
                let pat = SpmvPattern::build(&preset, part, rank, 3);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let (s, e) = part.range(rank);
                let x: Vec<f64> = (s..e).map(|g| (g % 13) as f64 - 6.0).collect();

                let a_p2p = DistMatrix::build(&preset, part, rank, 3, pkg.clone());
                let y_p2p = a_p2p.spmv(&c, &x).await;

                let mut a_std = DistMatrix::build(&preset, part, rank, 3, pkg.clone());
                a_std.init_halo(&mx, NeighborMethod::Standard).await;
                let y_std = a_std.spmv(&c, &x).await;

                let mut a_loc = DistMatrix::build(&preset, part, rank, 3, pkg);
                a_loc.init_halo(&mx, NeighborMethod::Locality).await;
                let y_loc = a_loc.spmv(&c, &x).await;

                (y_p2p, y_std, y_loc)
            }
        });
        out.results
    };

    let base = run(None);
    for seed in [4, 9, 23] {
        let faulted = run(Some(FaultPlan::with_profile(seed, FaultProfile::heavy())));
        for (rank, ((bp, bs, bl), (fp, fs, fl))) in base.iter().zip(&faulted).enumerate() {
            let as_bits =
                |v: &[f64]| v.iter().map(|y| y.to_bits()).collect::<Vec<u64>>();
            assert_eq!(as_bits(bp), as_bits(fp), "seed {seed} rank {rank}: p2p moved");
            assert_eq!(as_bits(bp), as_bits(bs), "rank {rank}: standard != p2p");
            assert_eq!(as_bits(fp), as_bits(fs), "seed {seed} rank {rank}: standard != p2p");
            assert_eq!(as_bits(fp), as_bits(fl), "seed {seed} rank {rank}: locality != p2p");
            assert_eq!(as_bits(bl), as_bits(fl), "seed {seed} rank {rank}: locality moved");
        }
    }
}

fn tiny_sweep() -> SweepConfig {
    let mut cfg = SweepConfig::quick(FigureId::Fig5, 400);
    cfg.nodes = vec![2, 4];
    cfg.matrices.truncate(1);
    cfg
}

fn csv_bytes(points: &[Point], name: &str) -> Vec<u8> {
    let path = std::env::temp_dir().join(format!("sdde_fault_inv_{name}_{}.csv", std::process::id()));
    write_csv(&path, points).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

/// `FaultPlan::off()` is not "very small faults" — it is the *absence* of
/// the fault layer: points and rendered CSV bytes are identical.
#[test]
fn off_plan_sweep_and_csv_are_bit_identical() {
    let base_cfg = tiny_sweep();
    let mut off_cfg = tiny_sweep();
    off_cfg.faults = Some(FaultPlan::off());
    let base = run_sweep(&base_cfg);
    let off = run_sweep(&off_cfg);
    assert_eq!(base, off, "FaultPlan::off() perturbed a sweep");
    assert_eq!(
        csv_bytes(&base, "base"),
        csv_bytes(&off, "off"),
        "CSV bytes differ under FaultPlan::off()"
    );
}

/// Chaos sweeps parallelize like everything else: per-cell fault streams
/// derive from (seed, cell index) — never from the worker thread — so a
/// faulted sweep at `--jobs 4` is byte-identical to serial (satellite of
/// invariant 7, with faults on).
#[test]
fn faulted_sweep_is_jobs_invariant_including_csv() {
    let mut serial_cfg = tiny_sweep();
    serial_cfg.faults = Some(FaultPlan::seeded(42));
    serial_cfg.jobs = 1;
    let mut par_cfg = serial_cfg.clone();
    par_cfg.jobs = 4;
    let serial = run_sweep(&serial_cfg);
    let par = run_sweep(&par_cfg);
    assert_eq!(serial, par, "faulted sweep changed under --jobs 4");
    assert_eq!(
        csv_bytes(&serial, "jobs1"),
        csv_bytes(&par, "jobs4"),
        "faulted sweep CSV bytes differ across jobs counts"
    );
    // And the faults actually bit: some point's virtual time moved.
    let mut base_cfg = tiny_sweep();
    base_cfg.jobs = 1;
    let base = run_sweep(&base_cfg);
    assert!(
        base.iter().zip(&serial).any(|(b, f)| b.time_ns != f.time_ns),
        "fault plan seeded(42) injected nothing"
    );
    // Traffic metrics never move (red-dot metrics are fault-invariant).
    for (b, f) in base.iter().zip(&serial) {
        assert_eq!(b.max_internode, f.max_internode, "{}/{}", b.matrix, b.nodes);
        assert_eq!(b.total_msgs, f.total_msgs, "{}/{}", b.matrix, b.nodes);
    }
}
