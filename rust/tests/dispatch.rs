//! Integration tests for the evidence-driven dispatch layer.
//!
//! The load-bearing invariant (DESIGN.md invariant 9): with no model
//! loaded, `Dispatch` must select exactly what the legacy threshold
//! heuristic selected — same algorithm, same virtual time, bit for bit.
//! On top of that: model JSON round-trips losslessly, the robustness
//! weight actually changes picks, and the embedded model disagrees with
//! its own fault-free ranking somewhere (otherwise shipping fault
//! evidence would be pointless).

use std::rc::Rc;

use sdde::bench::{pattern_set_stats, RunSpec, Variant};
use sdde::mpix::{dispatch, DispatchModel, ModelEntry, SddeAlgorithm, SelectionSource};
use sdde::simnet::{MpiFlavor, RegionKind, Topology};
use sdde::sparse::{MatrixPreset, Partition, SpmvPattern};

fn stats(nranks: usize, region: usize, nnz: usize, constant: bool) -> sdde::mpix::PatternStats {
    sdde::mpix::PatternStats {
        nranks,
        region_size: region,
        send_nnz: nnz,
        local_frac: 0.0,
        constant,
    }
}

/// The pre-redesign `resolve()` logic, transcribed verbatim as the oracle.
fn legacy_resolve(nranks: usize, region: usize, nnz: usize) -> SddeAlgorithm {
    if nnz > 2 * region && nranks >= 64 {
        SddeAlgorithm::LocalityNonBlocking
    } else if nranks >= 256 {
        SddeAlgorithm::NonBlocking
    } else {
        SddeAlgorithm::Personalized
    }
}

#[test]
fn no_model_dispatch_matches_legacy_resolve_over_the_grid() {
    // Grid straddles every threshold boundary: 63/64/65 ranks, 255/256/257
    // ranks, and send_nnz at exactly 2×region vs one past it.
    for &p in &[1, 2, 8, 16, 63, 64, 65, 128, 255, 256, 257, 1024] {
        for &region in &[1, 4, 8, 32] {
            for &nnz in &[0, 1, 2 * region, 2 * region + 1, 10 * region] {
                for &constant in &[true, false] {
                    let s = stats(p, region, nnz, constant);
                    let sel = dispatch::select(None, &s, None);
                    assert_eq!(
                        sel.algo,
                        legacy_resolve(p, region, nnz),
                        "heuristic diverged from legacy resolve at p={p} region={region} nnz={nnz}"
                    );
                    assert_eq!(sel.source, SelectionSource::Heuristic);
                    assert!(!sel.rationale.is_empty());
                }
            }
        }
    }
}

#[test]
fn embedded_model_round_trips_through_json() {
    let m = DispatchModel::embedded();
    let back = DispatchModel::from_json(&m.to_json()).expect("re-parse embedded model");
    assert_eq!(&back, m);
    assert!(!m.entries.is_empty());
    assert!(!m.profiles.is_empty());
}

/// Two algorithms, one bucket: `pers` is fastest fault-free but degrades
/// 2× under `heavy`; `nbx` is 20% slower but nearly flat. The robustness
/// weight alone must flip the pick.
fn synthetic_model(robustness: f64) -> DispatchModel {
    let entry = |algo, base: f64, infl: f64| ModelEntry {
        bucket: "small/sparse/crs".to_string(),
        algo,
        base,
        cp_wait: 0.0,
        inflation: vec![("heavy".to_string(), infl)],
    };
    DispatchModel {
        robustness,
        profiles: vec!["heavy".to_string()],
        entries: vec![
            entry(SddeAlgorithm::Personalized, 1.0, 2.0),
            entry(SddeAlgorithm::NonBlocking, 1.2, 1.05),
        ],
    }
}

#[test]
fn robustness_weight_flips_the_pick_under_noise() {
    let s = stats(16, 8, 4, true);
    assert_eq!(s.bucket(), "small/sparse/crs");

    // Fault-free regime: base cost alone decides, regardless of weight.
    for w in [0.0, 1.0] {
        let sel = dispatch::select(Some(&synthetic_model(w)), &s, None);
        assert_eq!(sel.algo, SddeAlgorithm::Personalized);
        assert_eq!(sel.source, SelectionSource::Model);
    }

    // Under heavy noise: w=0 ignores the evidence (pers: 1.0 beats 1.2),
    // w=1 weighs it at face value (pers: 2.0 loses to nbx: 1.26).
    let flat = dispatch::select(Some(&synthetic_model(0.0)), &s, Some("heavy"));
    assert_eq!(flat.algo, SddeAlgorithm::Personalized);
    let robust = dispatch::select(Some(&synthetic_model(1.0)), &s, Some("heavy"));
    assert_eq!(robust.algo, SddeAlgorithm::NonBlocking);
    assert!(
        robust.rationale.contains("heavy"),
        "rationale should name the noise regime: {}",
        robust.rationale
    );
    // The full score matrix rides along for the decision table.
    assert_eq!(robust.scores.len(), 2);
    assert!(robust.scores[0].score <= robust.scores[1].score);
}

#[test]
fn embedded_model_disagrees_with_fault_free_ranking_somewhere() {
    let m = DispatchModel::embedded();
    // One representative PatternStats per bucket axis combination.
    let mut flips = 0;
    for &(p, region) in &[(16, 8), (128, 8), (512, 8)] {
        for &nnz in &[4, 17] {
            for &constant in &[true, false] {
                let s = stats(p, region, nnz, constant);
                let base = dispatch::select(Some(m), &s, None).algo;
                for prof in &m.profiles {
                    if dispatch::select(Some(m), &s, Some(prof.as_str())).algo != base {
                        flips += 1;
                    }
                }
            }
        }
    }
    assert!(
        flips > 0,
        "embedded model never changes its pick under any noise profile — \
         the fault evidence is dead weight"
    );
}

/// End-to-end through a real world: `Dispatch` with no model must produce
/// the identical virtual time as explicitly running the heuristic's pick,
/// and with the embedded model loaded, the identical time as explicitly
/// running the model's pick.
#[test]
fn dispatch_is_bit_identical_to_its_resolved_algorithm_in_world() {
    let topo = Topology::quartz(2, 4);
    let nranks = topo.nranks();
    let preset = MatrixPreset::parse("cage14").unwrap().scaled(2000);
    let part = Partition::new(preset.n, nranks);
    let patterns: Rc<Vec<SpmvPattern>> = Rc::new(
        (0..nranks)
            .map(|r| SpmvPattern::build(&preset, part, r, 7))
            .collect(),
    );
    let s = pattern_set_stats(&topo, RegionKind::Node, Variant::Variable, &patterns);
    let spec = RunSpec::new(topo, MpiFlavor::Mvapich2).seed(7);

    // No model: fallback must be bit-identical to the legacy pick.
    let picked = dispatch::select(None, &s, None).algo;
    let auto = spec
        .clone()
        .algo(SddeAlgorithm::Dispatch)
        .run_sdde(Variant::Variable, patterns.clone());
    let explicit = spec
        .clone()
        .algo(picked)
        .run_sdde(Variant::Variable, patterns.clone());
    assert_eq!(auto.time_ns, explicit.time_ns);
    assert_eq!(
        auto.summary().user_msgs(),
        explicit.summary().user_msgs()
    );

    // Embedded model: same contract against the model's pick.
    let m = DispatchModel::embedded();
    let model_pick = dispatch::select(Some(m), &s, None).algo;
    let modeled = spec
        .clone()
        .algo(SddeAlgorithm::Dispatch)
        .dispatch(Some(m.clone()))
        .run_sdde(Variant::Variable, patterns.clone());
    let model_explicit = spec
        .clone()
        .algo(model_pick)
        .run_sdde(Variant::Variable, patterns);
    assert_eq!(modeled.time_ns, model_explicit.time_ns);
}
