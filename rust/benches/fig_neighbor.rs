//! Bench: steady-state persistent neighbor alltoallv (standard vs
//! locality-aware vs legacy p2p halo) across iteration counts, topologies
//! and both MPI presets. Scaled-down by default; `SDDE_BENCH_FULL=1` for
//! a larger sweep. `sdde neighbor` is the CLI equivalent with CSV output.

use sdde::bench::{
    render_neighbor_figure, resolve_jobs, run_neighbor_sweep_bench, NeighborSweepConfig,
};
use sdde::simnet::MpiFlavor;

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    let jobs = resolve_jobs(None); // SDDE_JOBS=N parallelizes the sweep
    for flavor in [MpiFlavor::Mvapich2, MpiFlavor::OpenMpi] {
        let mut cfg = if full {
            let mut c = NeighborSweepConfig::quick(flavor, 4);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c.iters = vec![1, 16, 256, 1024];
            c
        } else {
            let mut c = NeighborSweepConfig::quick(flavor, 64);
            c.nodes = vec![2, 4];
            c.iters = vec![1, 16, 128];
            c
        };
        cfg.jobs = jobs;
        let (points, bench) = run_neighbor_sweep_bench(&cfg);
        let title = format!(
            "Neighbor figure: persistent neighbor alltoallv using {}",
            flavor.name()
        );
        println!("{}", render_neighbor_figure(&title, &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n{}\n",
            points.len(),
            bench.wall_ns as f64 / 1e9,
            bench.render(&title)
        );
    }
}
