//! Bench: steady-state persistent neighbor alltoallv (standard vs
//! locality-aware vs legacy p2p halo) across iteration counts, topologies
//! and both MPI presets. Scaled-down by default; `SDDE_BENCH_FULL=1` for
//! a larger sweep. `sdde neighbor` is the CLI equivalent with CSV output.

use sdde::bench::{render_neighbor_figure, run_neighbor_sweep, NeighborSweepConfig};
use sdde::simnet::MpiFlavor;

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    for flavor in [MpiFlavor::Mvapich2, MpiFlavor::OpenMpi] {
        let cfg = if full {
            let mut c = NeighborSweepConfig::quick(flavor, 4);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c.iters = vec![1, 16, 256, 1024];
            c
        } else {
            let mut c = NeighborSweepConfig::quick(flavor, 64);
            c.nodes = vec![2, 4];
            c.iters = vec![1, 16, 128];
            c
        };
        let t0 = std::time::Instant::now();
        let points = run_neighbor_sweep(&cfg);
        let title = format!(
            "Neighbor figure: persistent neighbor alltoallv using {}",
            flavor.name()
        );
        println!("{}", render_neighbor_figure(&title, &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n",
            points.len(),
            t0.elapsed().as_secs_f64()
        );
    }
}
