//! Microbenchmarks of the simulated-MPI substrate: p2p latency per tier,
//! allreduce scaling, probe/matching costs, RMA puts — plus the *real*
//! throughput of the discrete-event engine (events/s, the §Perf metric).
//!
//! `cargo bench --bench micro_mpi`

use std::rc::Rc;

use sdde::mpi::{Payload, ReduceOp, World, ANY_SOURCE, ANY_TAG};
use sdde::simnet::{CostModel, MpiFlavor, Tier, Topology};
use sdde::util::fmt;

/// Host-side cost of one probe against an unexpected queue holding
/// `depth` + 1 messages (2 senders, probing under the given spec).
/// Returns real nanoseconds per iprobe call. The *charged* virtual cost
/// is unchanged by the host-side index — this measures the engine, not
/// the model.
fn probe_host_ns(depth: usize, spec: (usize, u32), iters: usize) -> f64 {
    let world = World::new(
        Topology::quartz(1, 3),
        CostModel::preset(MpiFlavor::Mvapich2),
    );
    let target = depth as u32 + 1;
    let out = world.run(move |c| async move {
        match c.rank() {
            0 => {
                // Filler from rank 0 with distinct tags, target last.
                for i in 0..depth {
                    c.isend(2, i as u32, Payload::ints(&[i as u64])).await;
                }
                c.isend(2, target, Payload::ints(&[0])).await;
                0.0
            }
            1 => {
                // A second source so ANY_SOURCE specs have real work.
                c.isend(2, target, Payload::ints(&[1])).await;
                0.0
            }
            _ => {
                c.sim().sleep(10_000_000).await; // let everything arrive
                let (src, tag) = spec;
                let t0 = std::time::Instant::now();
                for _ in 0..iters {
                    let info = c.iprobe(src, tag).await;
                    std::hint::black_box(&info);
                    assert!(info.is_some());
                }
                let per_op = t0.elapsed().as_nanos() as f64 / iters as f64;
                // Drain so the run ends with conserved queues.
                for i in 0..depth {
                    c.recv(0, i as u32).await;
                }
                c.recv(0, target).await;
                c.recv(1, target).await;
                per_op
            }
        }
    });
    out.results[2]
}

fn pingpong(topo: Topology, bytes_words: usize, iters: usize) -> u64 {
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let out = world.run(move |c| async move {
        let data = vec![1u64; bytes_words];
        if c.rank() == 0 {
            let t0 = c.now();
            for _ in 0..iters {
                c.send(1, 1, Payload::ints(&data)).await;
                c.recv(1, 2).await;
            }
            (c.now() - t0) / (2 * iters as u64)
        } else if c.rank() == 1 {
            for _ in 0..iters {
                let m = c.recv(0, 1).await;
                c.send(0, 2, m.payload).await;
            }
            0
        } else {
            0
        }
    });
    out.results[0]
}

fn main() {
    println!("== simulated p2p half-round-trip latency (4-word message) ==");
    for (name, topo) in [
        ("intra-socket", Topology::quartz(1, 4)),
        ("inter-socket", Topology::quartz(1, 2)),
        ("inter-node  ", Topology::quartz(2, 1)),
    ] {
        let t = pingpong(topo, 4, 100);
        println!("  {name}: {}", fmt::ns(t));
    }
    // tier sanity
    let t = Topology::quartz(2, 4);
    assert_eq!(t.tier(0, 4), Tier::InterNode);

    println!("\n== allreduce virtual time vs ranks (64-word vector) ==");
    for nodes in [1usize, 2, 4, 8, 16, 32, 64] {
        let world = World::new(
            Topology::quartz(nodes, 32),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = world.run(|c| async move {
            c.allreduce(vec![1u64; 64], ReduceOp::Sum).await;
        });
        println!(
            "  {:>5} ranks: {}",
            nodes * 32,
            fmt::ns(out.end_time)
        );
    }

    println!("\n== unexpected-queue matching cost (N queued, probe the last) ==");
    for n_queued in [1usize, 16, 64, 256] {
        let world = World::new(
            Topology::quartz(1, 2),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = world.run(move |c| async move {
            if c.rank() == 0 {
                for i in 0..n_queued {
                    c.isend(1, i as u32, Payload::ints(&[1])).await;
                }
                c.isend(1, 9999, Payload::ints(&[2])).await;
                0
            } else {
                c.sim().sleep(1_000_000).await; // let everything arrive
                let t0 = c.now();
                // probe for the *last* message → scans the whole queue
                c.probe(ANY_SOURCE, 9999).await;
                let dt = c.now() - t0;
                for i in 0..n_queued {
                    c.recv(0, i as u32).await;
                }
                c.recv(0, 9999).await;
                dt
            }
        });
        println!("  queue={n_queued:>4}: probe cost {}", fmt::ns(out.results[1]));
    }

    println!("\n== unexpected-queue matching HOST cost (real ns/iprobe) ==");
    println!("  (bucketed index: flat in depth; charged virtual cost unchanged)");
    for depth in [0usize, 16, 256, 4096] {
        let exact = probe_host_ns(depth, (0, depth as u32 + 1), 1000);
        let any_tag = probe_host_ns(depth, (0, ANY_TAG), 1000);
        let any_src = probe_host_ns(depth, (ANY_SOURCE, depth as u32 + 1), 1000);
        println!(
            "  depth={depth:>5}: exact {exact:>8.1} ns  any-tag {any_tag:>8.1} ns  \
             any-source {any_src:>8.1} ns"
        );
    }

    println!("\n== DES engine throughput (real time) ==");
    let t0 = std::time::Instant::now();
    let topo = Topology::quartz(8, 16);
    let n = topo.nranks();
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let rounds = 50usize;
    let out = world.run(move |c| async move {
        let me = c.rank();
        for r in 0..rounds {
            let dst = (me + r + 1) % n;
            let src = (me + n - (r + 1) % n) % n;
            let sreq = c.isend(dst, 7, Payload::ints(&[r as u64])).await;
            c.recv(src, 7).await;
            sreq.await;
        }
    });
    let real = t0.elapsed();
    let (events, polls) = (out.exec_stats.events_run, out.exec_stats.polls);
    let msgs = (n * rounds) as f64;
    println!(
        "  {} ranks x {} rounds: {} msgs, {events} events, {polls} polls in {:.3}s",
        n, rounds, msgs, real.as_secs_f64()
    );
    println!(
        "  => {:.2} M events/s, {:.2} us/message (real)",
        events as f64 / real.as_secs_f64() / 1e6,
        real.as_secs_f64() * 1e6 / msgs
    );

    println!("\n== RMA put + fence (const-size SDDE substrate) ==");
    for nodes in [2usize, 8, 32] {
        let world = World::new(
            Topology::quartz(nodes, 32),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = world.run(move |c| async move {
            let n = c.nranks();
            let win = c.win_allocate(n).await;
            win.fence().await;
            let me = c.rank();
            for k in 1..=8usize {
                win.put((me + k * 7) % n, me, &[me as u64], 4).await;
            }
            win.fence().await;
        });
        println!(
            "  {:>4} ranks, 8 puts/rank: {}",
            nodes * 32,
            fmt::ns(out.end_time)
        );
    }
}
