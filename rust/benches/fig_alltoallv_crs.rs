//! Bench: regenerate Figures 7 & 8 (`MPIX_Alltoallv_crs` cost across node
//! counts, Mvapich2 + OpenMPI presets). Scaled-down by default;
//! `SDDE_BENCH_FULL=1` for paper scale. See fig_alltoall_crs.rs.

use sdde::bench::{render_figure, resolve_jobs, run_sweep_bench, FigureId, SweepConfig};

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    let jobs = resolve_jobs(None); // SDDE_JOBS=N parallelizes the sweep
    for fig in [FigureId::Fig7, FigureId::Fig8] {
        let mut cfg = if full {
            SweepConfig::paper(fig)
        } else {
            let mut c = SweepConfig::quick(fig, 16);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c
        };
        cfg.jobs = jobs;
        let (points, bench) = run_sweep_bench(&cfg);
        println!("{}", render_figure(&fig.title(), &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n{}\n",
            points.len(),
            bench.wall_ns as f64 / 1e9,
            bench.render(&fig.title())
        );
    }
}
