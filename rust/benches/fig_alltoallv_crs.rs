//! Bench: regenerate Figures 7 & 8 (`MPIX_Alltoallv_crs` cost across node
//! counts, Mvapich2 + OpenMPI presets). Scaled-down by default;
//! `SDDE_BENCH_FULL=1` for paper scale. See fig_alltoall_crs.rs.

use sdde::bench::{render_figure, run_sweep, FigureId, SweepConfig};

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    for fig in [FigureId::Fig7, FigureId::Fig8] {
        let cfg = if full {
            SweepConfig::paper(fig)
        } else {
            let mut c = SweepConfig::quick(fig, 16);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c
        };
        let t0 = std::time::Instant::now();
        let points = run_sweep(&cfg);
        println!("{}", render_figure(&fig.title(), &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n",
            points.len(),
            t0.elapsed().as_secs_f64()
        );
    }
}
