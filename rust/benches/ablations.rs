//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Region granularity: node vs socket aggregation (paper §IV-D).
//! 2. Intra-region redistribution: personalized vs dense alltoallv
//!    (paper §IV-D "possible optimizations").
//! 3. known_recv_nnz: skipping the allreduce in the personalized method
//!    (the input/output `recv_nnz` of the paper's API, §III).
//! 4. Allreduce-vs-no-reduce crossover vs message count (paper §I).
//!
//! `cargo bench --bench ablations`

use std::rc::Rc;

use sdde::bench::figures::run_once;
use sdde::bench::{resolve_jobs, run_cells, ProgressSink, Variant};
use sdde::mpi::World;
use sdde::mpix::{alltoallv_crs, IntraAlgo, MpixComm, MpixInfo, SddeAlgorithm};
use sdde::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
use sdde::sparse::{MatrixPreset, Partition, SpmvPattern};
use sdde::util::{fmt, Rng};

fn patterns(preset: &MatrixPreset, topo: &Topology, seed: u64) -> Rc<Vec<SpmvPattern>> {
    let part = Partition::new(preset.n, topo.nranks());
    Rc::new(
        (0..topo.nranks())
            .map(|r| SpmvPattern::build(preset, part, r, seed))
            .collect(),
    )
}

fn main() {
    let topo = Topology::quartz(8, 16);
    let preset = MatrixPreset::cage14_like().scaled(8);
    println!(
        "workload: {} over {} ranks ({} nodes x {} ppn)\n",
        preset.name,
        topo.nranks(),
        topo.nodes,
        topo.ppn
    );
    let pats = patterns(&preset, &topo, 11);

    println!("== ablation 1: aggregation region (loc-nonblocking) ==");
    for region in [RegionKind::Node, RegionKind::Socket] {
        let (t, c) = run_once(
            topo.clone(),
            MpiFlavor::Mvapich2,
            SddeAlgorithm::LocalityNonBlocking,
            region,
            IntraAlgo::Personalized,
            Variant::Variable,
            pats.clone(),
        );
        println!(
            "  region={region:?}: {}  (max inter-node msgs {})",
            fmt::ns(t),
            c.max_internode_per_rank()
        );
    }

    println!("\n== ablation 2: intra-region redistribution (loc-personalized) ==");
    for intra in [IntraAlgo::Personalized, IntraAlgo::Alltoallv] {
        let (t, _) = run_once(
            topo.clone(),
            MpiFlavor::Mvapich2,
            SddeAlgorithm::LocalityPersonalized,
            RegionKind::Node,
            intra,
            Variant::Variable,
            pats.clone(),
        );
        println!("  intra={intra:?}: {}", fmt::ns(t));
    }

    println!("\n== ablation 3: known recv_nnz skips the allreduce ==");
    for known in [false, true] {
        let pats2 = pats.clone();
        let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let pats = pats2.clone();
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                // oracle recv_nnz: count ranks that need data from me
                let me = c.rank();
                let recv_nnz = pats
                    .iter()
                    .filter(|p| p.needed.iter().any(|(o, _)| *o == me))
                    .count();
                let info = MpixInfo {
                    algorithm: SddeAlgorithm::Personalized,
                    known_recv_nnz: known.then_some(recv_nnz),
                    ..MpixInfo::default()
                };
                c.barrier().await;
                let t0 = c.now();
                alltoallv_crs(&mx, &info, &pats[me].crsv_args())
                    .await
                    .unwrap();
                c.now() - t0
            }
        });
        let t = out.results.into_iter().max().unwrap();
        println!(
            "  known_recv_nnz={known}: {}  (allreduces: {})",
            fmt::ns(t),
            out.counters.allreduces
        );
    }

    println!("\n== extension: locality-aware RMA (paper §VI future work) ==");
    {
        // constant-size SDDE: compare plain RMA vs locality-aware RMA vs
        // the paper's best (loc-nonblocking)
        for algo in [
            SddeAlgorithm::Rma,
            SddeAlgorithm::LocalityRma,
            SddeAlgorithm::LocalityNonBlocking,
        ] {
            let (t, c) = run_once(
                topo.clone(),
                MpiFlavor::Mvapich2,
                algo,
                RegionKind::Node,
                IntraAlgo::Personalized,
                Variant::ConstSize,
                pats.clone(),
            );
            println!(
                "  {:<18} {}  (max inter-node msgs {})",
                algo.name(),
                fmt::ns(t),
                c.max_internode_per_rank()
            );
        }
    }

    println!("\n== ablation 4: personalized vs NBX crossover vs message count ==");
    println!("  (uniform random pattern, 128 ranks; paper §I trade-off)");
    // Independent cells (one per degree) — SDDE_JOBS=N runs them in
    // parallel with output identical to a serial run.
    let topo4 = Topology::quartz(8, 16);
    let degs = [2usize, 8, 32, 96];
    let (lines, _) = run_cells(
        resolve_jobs(None),
        degs.len(),
        ProgressSink::Silent,
        |i, _| {
            let deg = degs[i];
            let n = topo4.nranks();
            let part = Partition::new(n * 64, n);
            let mut rng = Rng::new(5);
            let pats4: Rc<Vec<SpmvPattern>> = Rc::new(
                (0..n)
                    .map(|r| {
                        let owners = rng.sample_distinct(n - 1, deg);
                        let cols: Vec<usize> = owners
                            .iter()
                            .map(|&o| {
                                let o = if o >= r { o + 1 } else { o };
                                part.start(o)
                            })
                            .collect();
                        SpmvPattern::from_columns(part, r, &cols)
                    })
                    .collect(),
            );
            let mut line = format!("  deg={deg:>3}: ");
            for algo in [SddeAlgorithm::Personalized, SddeAlgorithm::NonBlocking] {
                let (t, _) = run_once(
                    topo4.clone(),
                    MpiFlavor::Mvapich2,
                    algo,
                    RegionKind::Node,
                    IntraAlgo::Personalized,
                    Variant::Variable,
                    pats4.clone(),
                );
                line.push_str(&format!("{}={:<12} ", algo.name(), fmt::ns(t)));
            }
            line
        },
    );
    for line in lines {
        println!("{line}");
    }
}
