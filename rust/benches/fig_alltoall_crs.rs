//! Bench: regenerate Figures 5 & 6 (`MPIX_Alltoall_crs` cost across node
//! counts, Mvapich2 + OpenMPI presets).
//!
//! `cargo bench --bench fig_alltoall_crs` runs a scaled-down sweep by
//! default so the whole bench suite stays in CI budget; set
//! `SDDE_BENCH_FULL=1` for the paper-scale sweep (2–64 nodes × 32 PPN,
//! full-size matrices — several minutes). `sdde figures --fig 5` is the
//! CLI equivalent with CSV output.

use sdde::bench::{render_figure, run_sweep, FigureId, SweepConfig};

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    for fig in [FigureId::Fig5, FigureId::Fig6] {
        let cfg = if full {
            SweepConfig::paper(fig)
        } else {
            let mut c = SweepConfig::quick(fig, 16);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c
        };
        let t0 = std::time::Instant::now();
        let points = run_sweep(&cfg);
        println!("{}", render_figure(&fig.title(), &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n",
            points.len(),
            t0.elapsed().as_secs_f64()
        );
    }
}
