//! Bench: regenerate Figures 5 & 6 (`MPIX_Alltoall_crs` cost across node
//! counts, Mvapich2 + OpenMPI presets).
//!
//! `cargo bench --bench fig_alltoall_crs` runs a scaled-down sweep by
//! default so the whole bench suite stays in CI budget; set
//! `SDDE_BENCH_FULL=1` for the paper-scale sweep (2–64 nodes × 32 PPN,
//! full-size matrices — several minutes). `sdde figures --fig 5` is the
//! CLI equivalent with CSV output.

use sdde::bench::{render_figure, resolve_jobs, run_sweep_bench, FigureId, SweepConfig};

fn main() {
    let full = std::env::var("SDDE_BENCH_FULL").is_ok();
    let jobs = resolve_jobs(None); // SDDE_JOBS=N parallelizes the sweep
    for fig in [FigureId::Fig5, FigureId::Fig6] {
        let mut cfg = if full {
            SweepConfig::paper(fig)
        } else {
            let mut c = SweepConfig::quick(fig, 16);
            c.nodes = vec![2, 4, 8, 16];
            c.ppn = 16;
            c
        };
        cfg.jobs = jobs;
        let (points, bench) = run_sweep_bench(&cfg);
        println!("{}", render_figure(&fig.title(), &points));
        println!(
            "[bench] {} points in {:.1}s (real)\n{}\n",
            points.len(),
            bench.wall_ns as f64 / 1e9,
            bench.render(&fig.title())
        );
    }
}
