//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them from the
//! rust request path. Python never runs at request time — the interchange
//! format is HLO *text* (see /opt/xla-example/README.md: serialized
//! HloModuleProto from jax ≥ 0.5 is rejected by xla_extension 0.5.1).
//!
//! The artifact directory contains a `manifest.txt` with one line per
//! artifact: `spmv <rows_pad> <width> <xlen> <file>` or
//! `dot <n> <file>`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::solver::LocalSpmv;
use crate::sparse::BlockEll;

/// A loaded artifact set: PJRT client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    spmv: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
    dot: HashMap<usize, xla::PjRtLoadedExecutable>,
}

/// Manifest entry describing one artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ManifestEntry {
    Spmv {
        rows_pad: usize,
        width: usize,
        xlen: usize,
        file: String,
    },
    Dot {
        n: usize,
        file: String,
    },
}

/// Parse `manifest.txt` (one artifact per line, `#` comments).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = t.split_whitespace().collect();
        match f[0] {
            "spmv" if f.len() == 5 => out.push(ManifestEntry::Spmv {
                rows_pad: f[1].parse().context("rows_pad")?,
                width: f[2].parse().context("width")?,
                xlen: f[3].parse().context("xlen")?,
                file: f[4].to_string(),
            }),
            "dot" if f.len() == 3 => out.push(ManifestEntry::Dot {
                n: f[1].parse().context("n")?,
                file: f[2].to_string(),
            }),
            _ => bail!("manifest line {}: unrecognized entry: {t}", lineno + 1),
        }
    }
    Ok(out)
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.txt` onto the PJRT
    /// CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let mut rt = Runtime {
            client,
            dir: dir.to_path_buf(),
            spmv: HashMap::new(),
            dot: HashMap::new(),
        };
        for e in parse_manifest(&text)? {
            match e {
                ManifestEntry::Spmv {
                    rows_pad,
                    width,
                    xlen,
                    file,
                } => {
                    let exe = rt.compile(&file)?;
                    rt.spmv.insert((rows_pad, width, xlen), exe);
                }
                ManifestEntry::Dot { n, file } => {
                    let exe = rt.compile(&file)?;
                    rt.dot.insert(n, exe);
                }
            }
        }
        Ok(rt)
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Available SpMV shapes `(rows_pad, width, xlen)`.
    pub fn spmv_shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self.spmv.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Find the smallest SpMV artifact that fits `(rows, width, xlen)`.
    pub fn find_spmv(&self, rows: usize, width: usize, xlen: usize) -> Option<(usize, usize, usize)> {
        self.spmv_shapes()
            .into_iter()
            .find(|&(r, w, x)| r >= rows && w >= width && x >= xlen)
    }

    /// Execute the SpMV artifact for shape key `shape`:
    /// `y[i] = Σ_j vals[i,j] · x[cols[i,j]]`.
    pub fn run_spmv(
        &self,
        shape: (usize, usize, usize),
        vals: &[f32],
        cols: &[i32],
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let (rows_pad, width, xlen) = shape;
        let exe = self
            .spmv
            .get(&shape)
            .with_context(|| format!("no spmv artifact for shape {shape:?}"))?;
        anyhow::ensure!(vals.len() == rows_pad * width, "vals shape mismatch");
        anyhow::ensure!(cols.len() == rows_pad * width, "cols shape mismatch");
        anyhow::ensure!(x.len() == xlen, "x length mismatch");
        let lv = xla::Literal::vec1(vals).reshape(&[rows_pad as i64, width as i64])?;
        let lc = xla::Literal::vec1(cols).reshape(&[rows_pad as i64, width as i64])?;
        let lx = xla::Literal::vec1(x);
        let result = exe.execute::<xla::Literal>(&[lv, lc, lx])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute the dot artifact: `Σ a[i]·b[i]` for vectors of length `n`.
    pub fn run_dot(&self, n: usize, a: &[f32], b: &[f32]) -> Result<f32> {
        let exe = self
            .dot
            .get(&n)
            .with_context(|| format!("no dot artifact for n={n}"))?;
        anyhow::ensure!(a.len() == n && b.len() == n, "dot length mismatch");
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f32>()?;
        Ok(v[0])
    }
}

/// [`LocalSpmv`] backed by an XLA artifact: the E2E solver plugs this into
/// [`crate::solver::DistMatrix::spmv_with`] so every local SpMV runs the
/// AOT-compiled JAX/Pallas kernel.
pub struct XlaLocal<'a> {
    pub rt: &'a Runtime,
    pub shape: (usize, usize, usize),
    pub ell: BlockEll,
    /// Pre-padded scratch sizes.
    vals: Vec<f32>,
    cols: Vec<i32>,
}

impl<'a> XlaLocal<'a> {
    /// Pad the local Block-ELL matrix into the artifact's static shape.
    pub fn new(rt: &'a Runtime, ell: BlockEll) -> Result<XlaLocal<'a>> {
        let need_x = ell.ncols;
        let shape = rt
            .find_spmv(ell.rows_pad, ell.width, need_x)
            .with_context(|| {
                format!(
                    "no spmv artifact fits rows_pad={} width={} xlen={} (have {:?})",
                    ell.rows_pad,
                    ell.width,
                    need_x,
                    rt.spmv_shapes()
                )
            })?;
        let (rp, w, _) = shape;
        let mut vals = vec![0.0f32; rp * w];
        let mut cols = vec![0i32; rp * w];
        for r in 0..ell.rows_pad {
            for j in 0..ell.width {
                vals[r * w + j] = ell.vals[r * ell.width + j];
                cols[r * w + j] = ell.cols[r * ell.width + j];
            }
        }
        Ok(XlaLocal {
            rt,
            shape,
            ell,
            vals,
            cols,
        })
    }
}

impl LocalSpmv for XlaLocal<'_> {
    fn apply(&self, x_ext: &[f64]) -> Vec<f64> {
        let (_, _, xlen) = self.shape;
        let mut x = vec![0.0f32; xlen];
        for (i, &v) in x_ext.iter().enumerate() {
            x[i] = v as f32;
        }
        let y = self
            .rt
            .run_spmv(self.shape, &self.vals, &self.cols, &x)
            .expect("artifact execution failed");
        y[..self.ell.nrows].iter().map(|&v| v as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = parse_manifest(
            "# comment\n\
             spmv 1024 8 2048 spmv_1024x8_x2048.hlo.txt\n\
             dot 1024 dot_1024.hlo.txt\n\
             \n",
        )
        .unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(
            m[0],
            ManifestEntry::Spmv {
                rows_pad: 1024,
                width: 8,
                xlen: 2048,
                file: "spmv_1024x8_x2048.hlo.txt".into()
            }
        );
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("frobnicate 1 2\n").is_err());
        assert!(parse_manifest("spmv 1 2\n").is_err());
        assert!(parse_manifest("dot x file\n").is_err());
    }
}
