//! The simulated-MPI core: world/rank state, point-to-point messaging with
//! unexpected-message queues, eager/rendezvous protocols, synchronous-send
//! completion semantics, probes, and per-tier traffic counters.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::future::Future;
use std::hash::Hash;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

use super::watchdog::{self, BlockedOp, OpGuard, OpKind, WaitGraph};
use super::{CtxId, Tag, ANY_SOURCE, ANY_TAG, TAG_INTERNAL_BASE};
use crate::simnet::fault::{self, FaultState};
use crate::simnet::{CostModel, FaultPlan, Sim, SimHandle, SimStats, Tier, Time, Topology};
use crate::trace::{Event, EventKind, Trace, TraceConfig, TraceSummary, Tracer};
use crate::util::{FxHashMap, FxHashSet};

// ---------------------------------------------------------------------------
// Payload / message types
// ---------------------------------------------------------------------------

/// Message payload: `words` carry the logical data (indices, sizes, or
/// bit-cast doubles); `bytes` is the *wire* size used for costing, which
/// lets a payload of `u64` words model MPI_INT (4 B) messages faithfully.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Payload {
    pub words: Vec<u64>,
    pub bytes: usize,
}

impl Payload {
    pub fn empty() -> Payload {
        Payload::default()
    }

    /// MPI_INT-sized payload (4 bytes per element on the wire).
    pub fn ints(v: &[u64]) -> Payload {
        Payload {
            words: v.to_vec(),
            bytes: 4 * v.len(),
        }
    }

    /// 8-byte-per-element payload (MPI_LONG / MPI_DOUBLE).
    pub fn longs(v: &[u64]) -> Payload {
        Payload {
            words: v.to_vec(),
            bytes: 8 * v.len(),
        }
    }

    pub fn doubles(v: &[f64]) -> Payload {
        Payload {
            words: v.iter().map(|x| x.to_bits()).collect(),
            bytes: 8 * v.len(),
        }
    }

    pub fn as_doubles(&self) -> Vec<f64> {
        self.words.iter().map(|&w| f64::from_bits(w)).collect()
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }
}

/// A received message.
#[derive(Clone, Debug)]
pub struct Msg {
    pub src: usize,
    pub tag: Tag,
    pub payload: Payload,
}

/// Result of a (successful) probe: enough to size the receive buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbeInfo {
    pub src: usize,
    pub tag: Tag,
    /// Number of payload words.
    pub count: usize,
    /// Wire bytes.
    pub bytes: usize,
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

#[derive(Default)]
struct ReqState {
    done: bool,
    msg: Option<Msg>,
    wakers: Vec<Waker>,
    callbacks: Vec<Box<dyn FnOnce()>>,
}

/// Non-blocking operation handle (send or receive). Await it to wait for
/// completion; [`Request::is_done`] is the MPI_Test analog.
#[derive(Clone)]
pub struct Request {
    st: Rc<RefCell<ReqState>>,
}

impl Request {
    fn new() -> Request {
        Request {
            st: Rc::new(RefCell::new(ReqState::default())),
        }
    }

    fn complete(&self, msg: Option<Msg>) {
        let (wakers, callbacks) = {
            let mut st = self.st.borrow_mut();
            st.done = true;
            st.msg = msg;
            (
                std::mem::take(&mut st.wakers),
                std::mem::take(&mut st.callbacks),
            )
        };
        for w in wakers {
            w.wake();
        }
        for cb in callbacks {
            cb();
        }
    }

    /// MPI_Test: has the operation completed?
    pub fn is_done(&self) -> bool {
        self.st.borrow().done
    }

    /// Register a waker to fire on completion (no-op if already done).
    /// Re-registering the same task across polls is deduplicated.
    pub fn register_waker(&self, waker: &Waker) {
        let mut st = self.st.borrow_mut();
        if !st.done && !st.wakers.iter().any(|w| w.will_wake(waker)) {
            st.wakers.push(waker.clone());
        }
    }

    /// Run `cb` when the request completes (immediately if already done).
    pub fn on_complete(&self, cb: impl FnOnce() + 'static) {
        let mut st = self.st.borrow_mut();
        if st.done {
            drop(st);
            cb();
        } else {
            st.callbacks.push(Box::new(cb));
        }
    }

    /// Take the received message (receive requests only, after completion).
    pub fn take_msg(&self) -> Option<Msg> {
        self.st.borrow_mut().msg.take()
    }
}

impl Future for Request {
    type Output = Option<Msg>;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Option<Msg>> {
        let mut st = self.st.borrow_mut();
        if st.done {
            Poll::Ready(st.msg.take())
        } else {
            let waker = cx.waker();
            if !st.wakers.iter().any(|w| w.will_wake(waker)) {
                st.wakers.push(waker.clone());
            }
            Poll::Pending
        }
    }
}

/// Wait for every request to complete (MPI_Waitall).
pub async fn waitall(reqs: &[Request]) {
    for r in reqs {
        r.clone().await;
    }
}

// ---------------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------------

/// Per-tier traffic counters, split into *user* messages (tags below
/// [`TAG_INTERNAL_BASE`]) and *internal* ones (collectives/barriers), so the
/// figure harness can report the paper's red-dot metric (max inter-node
/// user messages per rank) without counting allreduce internals.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// [tier] -> messages (user tags).
    pub user_msgs: [u64; 4],
    /// [tier] -> wire bytes (user tags).
    pub user_bytes: [u64; 4],
    /// [tier] -> messages (internal tags).
    pub int_msgs: [u64; 4],
    /// [tier] -> wire bytes (internal tags).
    pub int_bytes: [u64; 4],
    /// Per-rank count of user inter-node sends.
    pub internode_sent: Vec<u64>,
    /// Number of allreduce invocations (any rank; counted on rank 0).
    pub allreduces: u64,
    /// Number of RMA puts.
    pub rma_puts: u64,
}

impl Counters {
    pub fn max_internode_per_rank(&self) -> u64 {
        self.internode_sent.iter().copied().max().unwrap_or(0)
    }
    pub fn total_user_msgs(&self) -> u64 {
        self.user_msgs.iter().sum()
    }
}

// ---------------------------------------------------------------------------
// Rank / world state
// ---------------------------------------------------------------------------

/// An arrived-but-unmatched message sitting in the unexpected queue, or the
/// RTS of a rendezvous message.
struct InMsg {
    /// Communicator context the message was sent on (envelope component).
    ctx: CtxId,
    /// World rank of the sender (translated to comm-local on match).
    src: usize,
    tag: Tag,
    payload: Payload,
    /// Rendezvous: payload bytes still need a data transfer after matching.
    rendezvous: bool,
    /// Synchronous send waiting for a match ack (the sender's request).
    sync_req: Option<Request>,
    /// Trace id linking this message back to its send event (0 untraced).
    msg_id: u64,
    /// Arrival sequence number (strictly increasing per rank).
    seq: u64,
}

struct RecvSpec {
    /// Context of the communicator the receive was posted on. Matching
    /// requires envelope ctx == spec ctx — no wildcard exists for it.
    ctx: CtxId,
    src: usize, // world rank, or ANY_SOURCE
    tag: Tag,   // or ANY_TAG
    req: Request,
    /// Post sequence number (strictly increasing per rank).
    seq: u64,
    /// Rank translation of the posting communicator (`None` = world), so
    /// the delivered [`Msg::src`] is comm-local for the caller.
    group: Option<Rc<CommGroup>>,
}

impl RecvSpec {
    /// Comm-local source rank for a message delivered into this spec.
    fn local_src(&self, world_src: usize) -> usize {
        match &self.group {
            Some(g) => g.to_local(world_src),
            None => world_src,
        }
    }
}

/// Remove `seq` from a bucket's seq list, dropping the bucket when empty
/// (collective tags carry sequence numbers, so live tag values are
/// unbounded over a run — empty buckets must not accumulate).
fn bucket_remove<K: Eq + Hash>(map: &mut FxHashMap<K, VecDeque<u64>>, key: K, seq: u64) {
    let Some(dq) = map.get_mut(&key) else {
        debug_assert!(false, "bucket missing for queued entry");
        return;
    };
    // Seq lists are in insertion order, i.e. sorted.
    let i = dq.partition_point(|&s| s < seq);
    debug_assert!(i < dq.len() && dq[i] == seq, "seq missing from bucket");
    dq.remove(i);
    if dq.is_empty() {
        map.remove(&key);
    }
}

/// Arrival-ordered unexpected-message queue with src/tag bucket indexes.
///
/// The buckets are host-side only: the *charged* queue-search cost is
/// always `match_cost(pos + 1)` for a match at arrival-order position
/// `pos` (and `match_cost(len)` on a miss) — exactly what a linear scan
/// of the arrival-ordered queue would charge. The indexes merely locate
/// that position in O(bucket front + log len) host work instead of O(len),
/// so virtual times are bit-for-bit unchanged while deep queues stop
/// costing host time per probe.
struct UnexpectedQueue {
    /// Messages in arrival order; `seq` strictly increasing ⇒ sorted.
    queue: VecDeque<InMsg>,
    next_seq: u64,
    /// Bumped on every push/remove. A receive that charged its match cost
    /// can skip the authoritative post-charge re-lookup when unchanged.
    epoch: u64,
    /// (ctx, src, tag) → seqs with exactly that envelope.
    by_src_tag: FxHashMap<(CtxId, usize, Tag), VecDeque<u64>>,
    /// (ctx, tag) → seqs (serves `ANY_SOURCE` + concrete-tag specs — NBX
    /// probes).
    by_tag: FxHashMap<(CtxId, Tag), VecDeque<u64>>,
    /// (ctx, src) → seqs (serves concrete-src + `ANY_TAG` specs).
    by_src: FxHashMap<(CtxId, usize), VecDeque<u64>>,
    /// ctx → seqs (serves the double-wildcard spec, which still cannot
    /// cross a communicator boundary).
    by_ctx: FxHashMap<CtxId, VecDeque<u64>>,
}

impl UnexpectedQueue {
    fn new() -> UnexpectedQueue {
        UnexpectedQueue {
            queue: VecDeque::new(),
            next_seq: 0,
            epoch: 0,
            by_src_tag: FxHashMap::default(),
            by_tag: FxHashMap::default(),
            by_src: FxHashMap::default(),
            by_ctx: FxHashMap::default(),
        }
    }

    fn push(&mut self, mut m: InMsg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.epoch += 1;
        m.seq = seq;
        self.by_src_tag
            .entry((m.ctx, m.src, m.tag))
            .or_default()
            .push_back(seq);
        self.by_tag.entry((m.ctx, m.tag)).or_default().push_back(seq);
        self.by_src.entry((m.ctx, m.src)).or_default().push_back(seq);
        self.by_ctx.entry(m.ctx).or_default().push_back(seq);
        self.queue.push_back(m);
    }

    /// Arrival-order position and seq of the first message matching the
    /// receive spec (wildcards allowed; ctx always concrete), via the
    /// bucket indexes. Debug builds cross-check the answer against the
    /// linear scan it replaces.
    fn first_match(&self, ctx: CtxId, src: usize, tag: Tag) -> Option<(usize, u64)> {
        let hit = self.first_match_indexed(ctx, src, tag);
        debug_assert_eq!(
            hit.map(|(pos, _)| pos),
            self.queue
                .iter()
                .position(|m| matches(ctx, src, tag, m.ctx, m.src, m.tag)),
            "bucket index disagrees with linear scan for spec (ctx {ctx}, {src}, {tag})"
        );
        hit
    }

    fn first_match_indexed(&self, ctx: CtxId, src: usize, tag: Tag) -> Option<(usize, u64)> {
        let seq = match (src == ANY_SOURCE, tag == ANY_TAG) {
            (false, false) => *self.by_src_tag.get(&(ctx, src, tag))?.front()?,
            (true, false) => *self.by_tag.get(&(ctx, tag))?.front()?,
            (false, true) => *self.by_src.get(&(ctx, src))?.front()?,
            (true, true) => *self.by_ctx.get(&ctx)?.front()?,
        };
        let pos = self.queue.partition_point(|m| m.seq < seq);
        debug_assert!(pos < self.queue.len() && self.queue[pos].seq == seq);
        Some((pos, seq))
    }

    /// The charged scan count for a lookup result: the scan stops at the
    /// match position, or touches the whole queue on a miss.
    fn scanned(&self, hit: Option<(usize, u64)>) -> usize {
        match hit {
            Some((pos, _)) => pos + 1,
            None => self.queue.len(),
        }
    }

    fn peek(&self, pos: usize) -> &InMsg {
        &self.queue[pos]
    }

    fn remove_at(&mut self, pos: usize) -> InMsg {
        let m = self
            .queue
            .remove(pos)
            .expect("unexpected-queue position out of range");
        self.epoch += 1;
        bucket_remove(&mut self.by_src_tag, (m.ctx, m.src, m.tag), m.seq);
        bucket_remove(&mut self.by_tag, (m.ctx, m.tag), m.seq);
        bucket_remove(&mut self.by_src, (m.ctx, m.src), m.seq);
        bucket_remove(&mut self.by_ctx, m.ctx, m.seq);
        m
    }
}

/// Post-ordered receive queue bucketed by spec shape: an arrival consults
/// at most four bucket fronts (exact, `ANY_SOURCE`, `ANY_TAG`, both) and
/// takes the earliest-posted candidate — the same winner, at the same
/// charged position, as the old linear scan in post order.
struct PostedQueue {
    /// Specs in post order; `seq` strictly increasing ⇒ sorted.
    queue: Vec<RecvSpec>,
    next_seq: u64,
    /// Spec (ctx, src, tag), src and tag concrete.
    exact: FxHashMap<(CtxId, usize, Tag), VecDeque<u64>>,
    /// Spec (ctx, `ANY_SOURCE`, tag).
    any_src: FxHashMap<(CtxId, Tag), VecDeque<u64>>,
    /// Spec (ctx, src, `ANY_TAG`).
    any_tag: FxHashMap<(CtxId, usize), VecDeque<u64>>,
    /// Spec (ctx, `ANY_SOURCE`, `ANY_TAG`) — wildcards never cross a
    /// communicator, so even the double wildcard is bucketed per ctx.
    any_any: FxHashMap<CtxId, VecDeque<u64>>,
}

impl PostedQueue {
    fn new() -> PostedQueue {
        PostedQueue {
            queue: Vec::new(),
            next_seq: 0,
            exact: FxHashMap::default(),
            any_src: FxHashMap::default(),
            any_tag: FxHashMap::default(),
            any_any: FxHashMap::default(),
        }
    }

    fn push(
        &mut self,
        ctx: CtxId,
        src: usize,
        tag: Tag,
        req: Request,
        group: Option<Rc<CommGroup>>,
    ) {
        let seq = self.next_seq;
        self.next_seq += 1;
        match (src == ANY_SOURCE, tag == ANY_TAG) {
            (false, false) => self.exact.entry((ctx, src, tag)).or_default().push_back(seq),
            (true, false) => self.any_src.entry((ctx, tag)).or_default().push_back(seq),
            (false, true) => self.any_tag.entry((ctx, src)).or_default().push_back(seq),
            (true, true) => self.any_any.entry(ctx).or_default().push_back(seq),
        }
        self.queue.push(RecvSpec {
            ctx,
            src,
            tag,
            req,
            seq,
            group,
        });
    }

    /// Post-order position of the first spec matching an arrival with
    /// envelope (ctx, src, tag) — all concrete here. Debug builds
    /// cross-check against the linear scan this replaces.
    fn first_match(&self, ctx: CtxId, src: usize, tag: Tag) -> Option<usize> {
        let hit = self.first_match_indexed(ctx, src, tag);
        debug_assert_eq!(
            hit,
            self.queue
                .iter()
                .position(|p| matches(p.ctx, p.src, p.tag, ctx, src, tag)),
            "posted index disagrees with linear scan for arrival (ctx {ctx}, {src}, {tag})"
        );
        hit
    }

    fn first_match_indexed(&self, ctx: CtxId, src: usize, tag: Tag) -> Option<usize> {
        let mut best: Option<u64> = None;
        let mut consider = |cand: Option<u64>| {
            if let Some(s) = cand {
                best = Some(best.map_or(s, |b| b.min(s)));
            }
        };
        consider(self.exact.get(&(ctx, src, tag)).and_then(|d| d.front().copied()));
        consider(self.any_src.get(&(ctx, tag)).and_then(|d| d.front().copied()));
        consider(self.any_tag.get(&(ctx, src)).and_then(|d| d.front().copied()));
        consider(self.any_any.get(&ctx).and_then(|d| d.front().copied()));
        let seq = best?;
        let pos = self.queue.partition_point(|p| p.seq < seq);
        debug_assert!(pos < self.queue.len() && self.queue[pos].seq == seq);
        Some(pos)
    }

    fn remove_at(&mut self, pos: usize) -> RecvSpec {
        let spec = self.queue.remove(pos);
        match (spec.src == ANY_SOURCE, spec.tag == ANY_TAG) {
            (false, false) => {
                bucket_remove(&mut self.exact, (spec.ctx, spec.src, spec.tag), spec.seq)
            }
            (true, false) => bucket_remove(&mut self.any_src, (spec.ctx, spec.tag), spec.seq),
            (false, true) => bucket_remove(&mut self.any_tag, (spec.ctx, spec.src), spec.seq),
            (true, true) => bucket_remove(&mut self.any_any, spec.ctx, spec.seq),
        }
        spec
    }
}

pub(crate) struct RankState {
    /// NIC busy-until (sender-side injection serialization).
    nic_free: Time,
    /// CPU busy-until (matching / software overheads serialize here).
    cpu_free: Time,
    unexpected: UnexpectedQueue,
    posted: PostedQueue,
    /// Bumped on every arrival; probe futures watch it.
    arrival_epoch: u64,
    arrival_wakers: Vec<Waker>,
    /// Reusable drain buffer for `arrival_wakers` — [`deliver`] swaps it in
    /// instead of allocating a fresh `Vec<Waker>` per message.
    wakers_scratch: Vec<Waker>,
    /// FIFO guard: per-destination last scheduled arrival time.
    last_arrival_to: FxHashMap<usize, Time>,
    /// RMA windows, keyed by (ctx, per-communicator window seq): collective
    /// allocation order *on the owning communicator* identifies a window
    /// across ranks even when other communicators allocate concurrently.
    pub(crate) windows: FxHashMap<(u32, u32), super::rma::WinState>,
    /// Blocked ops with no queue footprint (sync/rendezvous sends awaiting
    /// a match, blocking probes) — hang-diagnosis registry, host-side only.
    pending_ops: FxHashMap<u64, BlockedOp>,
    next_op_id: u64,
    /// Duplicate-delivery keys already seen by the matching layer (fault
    /// injection retransmits eager data; the first copy to arrive wins).
    /// Keyed by (ctx, dup key) — contexts never share a dedup slot.
    seen_dups: FxHashSet<(CtxId, u64)>,
}

impl RankState {
    fn new() -> RankState {
        RankState {
            nic_free: 0,
            cpu_free: 0,
            unexpected: UnexpectedQueue::new(),
            posted: PostedQueue::new(),
            arrival_epoch: 0,
            arrival_wakers: Vec::new(),
            wakers_scratch: Vec::new(),
            last_arrival_to: FxHashMap::default(),
            windows: FxHashMap::default(),
            pending_ops: FxHashMap::default(),
            next_op_id: 0,
            seen_dups: FxHashSet::default(),
        }
    }

    /// Hang diagnosis: (ctx, src, tag) spec of every posted receive, post
    /// order (src is a world rank or `ANY_SOURCE`).
    pub(crate) fn watchdog_recvs(&self) -> Vec<(CtxId, usize, Tag)> {
        self.posted
            .queue
            .iter()
            .map(|s| (s.ctx, s.src, s.tag))
            .collect()
    }

    /// Hang diagnosis: envelopes in the unexpected queue, arrival order.
    pub(crate) fn watchdog_unexpected(&self) -> Vec<(CtxId, usize, Tag)> {
        self.unexpected
            .queue
            .iter()
            .map(|m| (m.ctx, m.src, m.tag))
            .collect()
    }

    /// Hang diagnosis: registered blocked ops in registration order.
    pub(crate) fn watchdog_ops(&self) -> Vec<BlockedOp> {
        let mut ids: Vec<u64> = self.pending_ops.keys().copied().collect();
        ids.sort_unstable();
        ids.iter().map(|id| self.pending_ops[id].clone()).collect()
    }
}

/// Per-(rank, communicator) state shared by every clone of one `Comm`
/// handle: tag-family sequence numbers and the collective-call counter
/// used to pair up `dup`/`split` invocations across ranks.
pub(crate) struct CommState {
    /// Per-family tag sequence numbers — previously world-shared in
    /// `RankState`; per-communicator so `dup()`ed comms never interleave.
    seqs: RefCell<FxHashMap<Tag, u32>>,
    /// Number of `dup`/`split` calls issued on this comm by this rank.
    /// Collective call order is the MPI contract, so the counter agrees
    /// across member ranks and pairs registrations without RNG.
    split_seq: Cell<u32>,
}

impl CommState {
    fn new() -> CommState {
        CommState {
            seqs: RefCell::new(FxHashMap::default()),
            split_seq: Cell::new(0),
        }
    }
}

/// Rank translation for a split communicator: comm-local ↔ world.
pub(crate) struct CommGroup {
    /// comm-local rank → world rank, ascending by split (key, world rank).
    world_of: Vec<usize>,
    /// world rank → comm-local rank (`usize::MAX` for non-members).
    local_of: Vec<usize>,
}

impl CommGroup {
    fn new(world_of: Vec<usize>, nranks_world: usize) -> CommGroup {
        let mut local_of = vec![usize::MAX; nranks_world];
        for (local, &world) in world_of.iter().enumerate() {
            local_of[world] = local;
        }
        CommGroup { world_of, local_of }
    }

    fn to_world(&self, local: usize) -> usize {
        self.world_of[local]
    }

    fn to_local(&self, world: usize) -> usize {
        self.local_of[world]
    }
}

/// One in-flight (or completed) collective `dup`/`split`, keyed in
/// `WorldState::splits` by (parent ctx, parent split seq). Members
/// register before the parent-comm barrier; contexts are minted once, in
/// ascending color order, after all registrations are visible.
#[derive(Default)]
struct SplitRecord {
    /// (world rank, color, key) per registered member.
    members: Vec<(usize, u64, i64)>,
    /// color → minted child context.
    minted: FxHashMap<u64, CtxId>,
}

pub(crate) struct WorldState {
    pub(crate) topo: Topology,
    pub(crate) cost: CostModel,
    pub(crate) sim: SimHandle,
    pub(crate) ranks: Vec<RefCell<RankState>>,
    pub(crate) counters: RefCell<Counters>,
    /// Per-rank `CommState` of the world communicator, so separately
    /// obtained `World::comm(rank)` handles share sequence numbers (the
    /// pre-context behavior of the world-global `coll_seq`).
    world_comms: Vec<Rc<CommState>>,
    /// Context allocator: next fresh id (0 is reserved for the world, so
    /// single-communicator runs never observe a minted context).
    next_ctx: Cell<u32>,
    /// Split/dup rendezvous registry (see [`SplitRecord`]).
    splits: RefCell<FxHashMap<(u32, u32), SplitRecord>>,
    /// Shared per-node NIC: transmit-side busy-until (inter-node messages
    /// from all of a node's ranks serialize here — one HFI per node).
    pub(crate) node_tx_free: Vec<Cell<Time>>,
    /// Shared per-node NIC: receive-side busy-until.
    pub(crate) node_rx_free: Vec<Cell<Time>>,
    /// Event recorder (disabled by default; see [`World::with_trace`]).
    pub(crate) tracer: Tracer,
    /// Seeded fault injection (None unless the world was built with an
    /// active [`FaultPlan`] — the plan-off path allocates nothing and
    /// touches no RNG, keeping fault-free runs bit-identical).
    pub(crate) faults: Option<FaultState>,
    /// Allocator for duplicate-delivery dedup keys.
    next_dup_id: Cell<u64>,
}

impl WorldState {
    /// Register a blocked op for hang diagnosis; returns its registry id.
    pub(crate) fn register_op(&self, rank: usize, op: BlockedOp) -> u64 {
        let mut r = self.ranks[rank].borrow_mut();
        let id = r.next_op_id;
        r.next_op_id += 1;
        r.pending_ops.insert(id, op);
        id
    }

    /// Remove a blocked op once its wait ends (idempotent).
    pub(crate) fn unregister_op(&self, rank: usize, id: u64) {
        self.ranks[rank].borrow_mut().pending_ops.remove(&id);
    }

    /// Trace one injected fault event (`code` is a `fault::FAULT_*` const,
    /// carried in the tag field; the span is the injected delay, zero-width
    /// for delayless perturbations). No-op when tracing is disabled.
    pub(crate) fn record_fault(
        &self,
        rank: usize,
        peer: usize,
        code: u32,
        tier: Tier,
        t_start: Time,
        t_end: Time,
    ) {
        if self.tracer.enabled() {
            self.tracer.record(Event {
                kind: EventKind::Fault,
                // Faults perturb the transport, which is context-blind:
                // attribute them to the world context.
                ctx: CtxId::WORLD,
                rank,
                peer,
                tag: code,
                bytes: 0,
                tier,
                t_start,
                t_end,
                msg_id: 0,
            });
        }
    }
    /// Compute (inject_end, arrival) for a transfer and book the shared
    /// resources: the sender's per-rank NIC pipe, the *per-node* shared
    /// NIC on both sides for inter-node messages (the Quartz HFI — this
    /// contention is the scaling bottleneck the paper's aggregation
    /// attacks), the wire, and the per-(src,dst) FIFO guard.
    pub(crate) fn transfer_times(
        &self,
        src: usize,
        dst: usize,
        tier: Tier,
        inj_bytes: usize,
        wire_bytes: usize,
    ) -> (Time, Time) {
        let now = self.sim.now();
        let inject_end = {
            let mut r = self.ranks[src].borrow_mut();
            let mut start = r.nic_free.max(now);
            if tier == Tier::InterNode {
                let node = self.topo.node_of(src);
                start = start.max(self.node_tx_free[node].get());
            }
            let end = start + self.cost.inject_time(tier, inj_bytes);
            r.nic_free = end;
            if tier == Tier::InterNode {
                self.node_tx_free[self.topo.node_of(src)].set(end);
            }
            end
        };
        let mut arrival = inject_end + self.cost.wire_time(tier, wire_bytes);
        // Fault injection: per-message latency jitter, applied *before* the
        // FIFO guard below so per-(src,dst) non-overtaking is preserved by
        // construction — only the interleaving across pairs is perturbed.
        if let Some(f) = &self.faults {
            let extra = f.jitter(src);
            if extra > 0 {
                self.record_fault(src, dst, fault::FAULT_JITTER, tier, arrival, arrival + extra);
                arrival += extra;
            }
        }
        if tier == Tier::InterNode {
            let node = self.topo.node_of(dst);
            let rx = &self.node_rx_free[node];
            arrival = arrival.max(rx.get()) + self.cost.rx_gap;
            rx.set(arrival);
        }
        // FIFO guard: arrivals from src to dst must be non-decreasing.
        let mut r = self.ranks[src].borrow_mut();
        let last = r.last_arrival_to.entry(dst).or_insert(0);
        let a = arrival.max(*last + 1);
        *last = a;
        (inject_end, a)
    }
}

/// The simulated cluster: builds the executor, spawns one task per rank,
/// runs to completion, and reports virtual time + traffic counters.
pub struct World {
    sim: Sim,
    state: Rc<WorldState>,
}

/// Output of [`World::run`].
pub struct RunOutput<R> {
    /// Per-rank return values of the rank program.
    pub results: Vec<R>,
    /// Virtual time at which the last rank finished.
    pub end_time: Time,
    /// Traffic counters accumulated over the run.
    pub counters: Counters,
    /// Executor statistics (events run, futures polled).
    pub exec_stats: SimStats,
    /// Everything the tracer recorded (empty unless the world was built
    /// with [`World::with_trace`]).
    pub trace: Trace,
}

/// Configures a [`World`] before construction: tracing, fault injection,
/// and the quiescence watchdog. `World::new`/`with_trace` are thin
/// wrappers over the all-defaults paths.
pub struct WorldBuilder {
    topo: Topology,
    cost: CostModel,
    trace: TraceConfig,
    faults: Option<FaultPlan>,
    quiet_horizon: Option<Time>,
}

impl WorldBuilder {
    /// Enable tracing ([`TraceConfig::counters_only`] for rollups,
    /// [`TraceConfig::full`] for exportable event traces). Tracing is
    /// host-side only — it never changes virtual times.
    pub fn trace(mut self, trace: TraceConfig) -> WorldBuilder {
        self.trace = trace;
        self
    }

    /// Install a seeded fault plan. `None` or an inactive plan (profile
    /// `off`) leaves the world bit-identical to an unfaulted one.
    pub fn faults(mut self, plan: Option<FaultPlan>) -> WorldBuilder {
        self.faults = plan;
        self
    }

    /// Arm the virtual-time quiescence watchdog: if no delivery-level
    /// progress happens for `horizon` virtual ns while tasks are still
    /// live, the run stalls with a [`WaitGraph`] instead of spinning
    /// forever. Purely observational for runs that keep making progress.
    pub fn watchdog(mut self, horizon: Time) -> WorldBuilder {
        self.quiet_horizon = Some(horizon);
        self
    }

    pub fn build(self) -> World {
        let sim = Sim::new();
        sim.set_quiet_horizon(self.quiet_horizon);
        let n = self.topo.nranks();
        let nodes = self.topo.nodes;
        let faults = self
            .faults
            .filter(|p| p.is_active())
            .map(|p| FaultState::new(p, n));
        let state = Rc::new(WorldState {
            topo: self.topo,
            cost: self.cost,
            sim: sim.handle(),
            ranks: (0..n).map(|_| RefCell::new(RankState::new())).collect(),
            counters: RefCell::new(Counters {
                internode_sent: vec![0; n],
                ..Counters::default()
            }),
            world_comms: (0..n).map(|_| Rc::new(CommState::new())).collect(),
            next_ctx: Cell::new(1),
            splits: RefCell::new(FxHashMap::default()),
            node_tx_free: (0..nodes).map(|_| Cell::new(0)).collect(),
            node_rx_free: (0..nodes).map(|_| Cell::new(0)).collect(),
            tracer: Tracer::new(self.trace, n),
            faults,
            next_dup_id: Cell::new(0),
        });
        World { sim, state }
    }
}

impl World {
    pub fn new(topo: Topology, cost: CostModel) -> World {
        World::builder(topo, cost).build()
    }

    /// Start configuring a world (tracing / faults / watchdog).
    pub fn builder(topo: Topology, cost: CostModel) -> WorldBuilder {
        WorldBuilder {
            topo,
            cost,
            trace: TraceConfig::off(),
            faults: None,
            quiet_horizon: None,
        }
    }

    /// Like [`World::new`], but with tracing enabled per `trace`.
    pub fn with_trace(topo: Topology, cost: CostModel, trace: TraceConfig) -> World {
        World::builder(topo, cost).trace(trace).build()
    }

    /// Communicator handle for `rank` (used by [`World::run`]'s closure via
    /// the argument it receives; exposed for custom spawning in tests).
    pub fn comm(&self, rank: usize) -> Comm {
        Comm {
            comm_state: self.state.world_comms[rank].clone(),
            state: self.state.clone(),
            rank,
            ctx: CtxId::WORLD,
            group: None,
        }
    }

    pub fn topo(&self) -> &Topology {
        &self.state.topo
    }

    /// Run `prog(comm)` on every rank to completion; returns per-rank
    /// results, the virtual end time and traffic counters. A stalled
    /// simulation (deadlock, or watchdog-detected quiescence) panics with
    /// the rendered [`WaitGraph`] diagnostic; use [`World::run_checked`]
    /// to get the diagnostic as a value instead.
    pub fn run<R, F, Fut>(self, prog: F) -> RunOutput<R>
    where
        R: 'static,
        F: Fn(Comm) -> Fut,
        Fut: Future<Output = R> + 'static,
    {
        match self.run_checked(prog) {
            Ok(out) => out,
            Err(wg) => panic!("simulation deadlock: ranks stalled\n{}", wg.render()),
        }
    }

    /// Like [`World::run`], but a stalled simulation returns the
    /// [`WaitGraph`] diagnostic (per-rank blocked ops, near-miss
    /// unexpected envelopes, wait cycle) instead of panicking.
    pub fn run_checked<R, F, Fut>(self, prog: F) -> Result<RunOutput<R>, WaitGraph>
    where
        R: 'static,
        F: Fn(Comm) -> Fut,
        Fut: Future<Output = R> + 'static,
    {
        let n = self.state.topo.nranks();
        let results: Rc<RefCell<Vec<Option<R>>>> =
            Rc::new(RefCell::new((0..n).map(|_| None).collect()));
        for rank in 0..n {
            let comm = self.comm(rank);
            let fut = prog(comm);
            let results = results.clone();
            self.sim.spawn(async move {
                let r = fut.await;
                results.borrow_mut()[rank] = Some(r);
            });
        }
        let end_time = match self.sim.try_run() {
            Ok(t) => t,
            Err(stall) => return Err(watchdog::collect_wait_graph(&self.state, stall)),
        };
        let counters = self.state.counters.borrow().clone();
        let exec_stats = self.sim.stats();
        let trace = self.state.tracer.take();
        let results = Rc::try_unwrap(results)
            .ok()
            .expect("rank results still borrowed")
            .into_inner()
            .into_iter()
            .map(|r| r.expect("rank did not finish"))
            .collect();
        Ok(RunOutput {
            results,
            end_time,
            counters,
            exec_stats,
            trace,
        })
    }
}

// ---------------------------------------------------------------------------
// Comm: the per-rank MPI handle
// ---------------------------------------------------------------------------

/// Per-rank communicator handle — the `MPI_COMM_WORLD` analog passed to
/// every simulated rank program. Derived communicators (from
/// [`Comm::dup`] / [`Comm::split`]) carry their own context id and rank
/// group; `rank()`, `nranks()`, and every src/dst argument are
/// comm-local, exactly as in MPI.
#[derive(Clone)]
pub struct Comm {
    pub(crate) state: Rc<WorldState>,
    /// World rank (indexes `WorldState::ranks`, counters, trace events).
    pub(crate) rank: usize,
    /// Context id: the envelope component that isolates this comm's
    /// traffic ([`CtxId::WORLD`] for the world communicator).
    ctx: CtxId,
    /// Per-(rank, comm) tag sequences + collective-call counter.
    comm_state: Rc<CommState>,
    /// Rank translation; `None` = world group (identity).
    group: Option<Rc<CommGroup>>,
}

/// Envelope match: ctx must be equal (no wildcard), src/tag admit
/// `ANY_SOURCE`/`ANY_TAG`.
fn matches(
    spec_ctx: CtxId,
    spec_src: usize,
    spec_tag: Tag,
    ctx: CtxId,
    src: usize,
    tag: Tag,
) -> bool {
    spec_ctx == ctx
        && (spec_src == ANY_SOURCE || spec_src == src)
        && (spec_tag == ANY_TAG || spec_tag == tag)
}

impl Comm {
    /// Comm-local rank of this process.
    pub fn rank(&self) -> usize {
        match &self.group {
            Some(g) => g.to_local(self.rank),
            None => self.rank,
        }
    }

    /// Number of ranks in this communicator's group.
    pub fn nranks(&self) -> usize {
        match &self.group {
            Some(g) => g.world_of.len(),
            None => self.state.topo.nranks(),
        }
    }

    /// World rank of this process (stable across splits; what topology,
    /// counters, and trace events are keyed by).
    pub fn world_rank(&self) -> usize {
        self.rank
    }

    /// This communicator's context id.
    pub fn ctx(&self) -> CtxId {
        self.ctx
    }

    /// World rank of comm-local rank `r` (wildcards pass through).
    pub fn to_world(&self, r: usize) -> usize {
        match &self.group {
            Some(g) if r != ANY_SOURCE => g.to_world(r),
            _ => r,
        }
    }

    /// Comm-local rank of world rank `r` (`usize::MAX` for non-members;
    /// wildcards pass through).
    pub fn to_local(&self, r: usize) -> usize {
        match &self.group {
            Some(g) if r != ANY_SOURCE => g.to_local(r),
            _ => r,
        }
    }

    /// Duplicate this communicator: same group and rank order, fresh
    /// context and tag sequences. Collective over the comm; deterministic
    /// (no RNG — contexts are minted from call order).
    pub async fn dup(&self) -> Comm {
        let me = self.rank();
        self.split(0, me as i64).await
    }

    /// MPI_Comm_split: ranks sharing `color` form a new communicator,
    /// ordered by (`key`, world rank). Collective over the comm (every
    /// member must call, in the same collective order); deterministic.
    pub async fn split(&self, color: u64, key: i64) -> Comm {
        // Pair this call with the peers' via the per-comm collective call
        // counter, then make every registration visible before any read by
        // running a barrier on the *parent* communicator.
        let seq = self.comm_state.split_seq.get();
        self.comm_state.split_seq.set(seq + 1);
        let slot = (self.ctx.0, seq);
        self.state
            .splits
            .borrow_mut()
            .entry(slot)
            .or_default()
            .members
            .push((self.rank, color, key));
        self.barrier().await;

        let (ctx, world_of) = {
            let mut splits = self.state.splits.borrow_mut();
            let rec = splits.get_mut(&slot).expect("split record vanished");
            // Mint child contexts once, in ascending color order, so ids
            // are a function of the registered set alone (not of which
            // member rank happens to exit the barrier first).
            if rec.minted.is_empty() {
                let mut colors: Vec<u64> = rec.members.iter().map(|&(_, c, _)| c).collect();
                colors.sort_unstable();
                colors.dedup();
                for c in colors {
                    let id = self.state.next_ctx.get();
                    self.state.next_ctx.set(id + 1);
                    rec.minted.insert(c, CtxId(id));
                }
            }
            let ctx = rec.minted[&color];
            let mut members: Vec<(i64, usize)> = rec
                .members
                .iter()
                .filter(|&&(_, c, _)| c == color)
                .map(|&(r, _, k)| (k, r))
                .collect();
            members.sort_unstable();
            (ctx, members.into_iter().map(|(_, r)| r).collect::<Vec<usize>>())
        };
        debug_assert!(world_of.contains(&self.rank));
        let group = Rc::new(CommGroup::new(world_of, self.state.topo.nranks()));
        Comm {
            state: self.state.clone(),
            rank: self.rank,
            ctx,
            comm_state: Rc::new(CommState::new()),
            group: Some(group),
        }
    }

    pub fn topo(&self) -> &Topology {
        &self.state.topo
    }

    pub fn cost(&self) -> &CostModel {
        &self.state.cost
    }

    pub fn now(&self) -> Time {
        self.state.sim.now()
    }

    pub fn sim(&self) -> &SimHandle {
        &self.state.sim
    }

    /// Charge `cost` ns to this rank's CPU and wait until it is done.
    /// (Matching, packing, software overheads all serialize here.)
    pub async fn charge_cpu(&self, cost: Time) {
        // Fault injection: inside a straggler episode this rank's CPU work
        // is dilated (drawless — a pure function of rank and virtual time).
        let cost = match &self.state.faults {
            Some(f) => {
                let now = self.state.sim.now();
                let slowed = f.slowed(self.rank, now, cost);
                if slowed > cost {
                    self.state.record_fault(
                        self.rank,
                        self.rank,
                        fault::FAULT_STRAGGLER,
                        Tier::SelfMsg,
                        now,
                        now + (slowed - cost),
                    );
                }
                slowed
            }
            None => cost,
        };
        let until = {
            let mut r = self.state.ranks[self.rank].borrow_mut();
            let start = r.cpu_free.max(self.state.sim.now());
            r.cpu_free = start + cost;
            r.cpu_free
        };
        if cost > 0 && self.state.tracer.enabled() {
            self.state.tracer.record(Event {
                kind: EventKind::CpuCharge,
                ctx: self.ctx,
                rank: self.rank,
                peer: self.rank,
                tag: 0,
                bytes: 0,
                tier: Tier::SelfMsg,
                t_start: until - cost,
                t_end: until,
                msg_id: 0,
            });
        }
        self.state.sim.sleep_until(until).await;
    }

    // -- sends --------------------------------------------------------------

    /// Non-blocking standard send (eager below the eager limit, rendezvous
    /// above). The returned request completes per MPI semantics: eager
    /// sends complete once buffered/injected; rendezvous sends complete
    /// when the receiver has matched and pulled the data.
    pub async fn isend(&self, dst: usize, tag: Tag, payload: Payload) -> Request {
        self.send_impl(dst, tag, payload, false).await
    }

    /// Non-blocking synchronous send (MPI_Issend): the request completes
    /// only after the destination has *matched* the message (NBX relies on
    /// this).
    pub async fn issend(&self, dst: usize, tag: Tag, payload: Payload) -> Request {
        self.send_impl(dst, tag, payload, true).await
    }

    async fn send_impl(&self, dst: usize, tag: Tag, payload: Payload, sync: bool) -> Request {
        let st = &self.state;
        assert!(dst < self.nranks(), "send to invalid rank {dst}");
        // Everything below the translation works in world ranks.
        let dst = self.to_world(dst);
        let ctx = self.ctx;
        let tier = st.topo.tier(self.rank, dst);
        let bytes = payload.bytes;
        let mut rendezvous = st.cost.is_rendezvous(bytes) && tier != Tier::SelfMsg;
        // Fault injection: force an eager-eligible message down the
        // rendezvous path (models an exhausted eager-buffer pool). The
        // protocol choice changes timing only — never message content.
        if !rendezvous && tier != Tier::SelfMsg {
            if let Some(f) = &st.faults {
                if f.force_rendezvous(self.rank) {
                    rendezvous = true;
                    let now = st.sim.now();
                    st.record_fault(self.rank, dst, fault::FAULT_RENDEZVOUS, tier, now, now);
                }
            }
        }

        // Software posting overhead on the sender CPU.
        self.charge_cpu(st.cost.post_overhead).await;

        // Count traffic at injection time.
        {
            let mut c = st.counters.borrow_mut();
            let t = tier as usize;
            if tag < TAG_INTERNAL_BASE {
                c.user_msgs[t] += 1;
                c.user_bytes[t] += bytes as u64;
                if tier == Tier::InterNode {
                    c.internode_sent[self.rank] += 1;
                }
            } else {
                c.int_msgs[t] += 1;
                c.int_bytes[t] += bytes as u64;
            }
        }

        // NIC serialization (per-rank pipe + shared per-node NIC) and wire.
        // Rendezvous injects only the RTS here; the data bytes are charged
        // when the receiver matches.
        let t_inject = st.sim.now();
        let xfer_bytes = if rendezvous { 16 } else { bytes };
        let (inject_end, arrival) =
            st.transfer_times(self.rank, dst, tier, xfer_bytes, xfer_bytes);

        let msg_id = st.tracer.next_msg_id();
        if st.tracer.enabled() {
            st.tracer.record(Event {
                kind: if rendezvous {
                    EventKind::RendezvousSend
                } else {
                    EventKind::EagerSend
                },
                ctx,
                rank: self.rank,
                peer: dst,
                tag,
                bytes,
                tier,
                t_start: t_inject,
                t_end: arrival,
                msg_id,
            });
        }

        let req = Request::new();
        // Eager non-sync sends complete at local injection completion.
        if !sync && !rendezvous {
            let req2 = req.clone();
            st.sim.schedule(inject_end, move || req2.complete(None));
        }

        let src = self.rank;
        let sync_req = if sync || rendezvous {
            Some(req.clone())
        } else {
            None
        };

        // Hang diagnosis: a send that waits on the receiver is registered
        // until its request completes (host-side only; no virtual cost).
        if sync || rendezvous {
            let kind = if sync {
                OpKind::SyncSend
            } else {
                OpKind::RendezvousSend
            };
            let op_id = st.register_op(
                src,
                BlockedOp {
                    kind,
                    ctx,
                    peer: dst,
                    tag,
                    since: Some(st.sim.now()),
                },
            );
            let weak = Rc::downgrade(st);
            req.on_complete(move || {
                if let Some(s) = weak.upgrade() {
                    s.unregister_op(src, op_id);
                }
            });
        }

        // Fault injection: bounded retransmit-style duplicate delivery of
        // eager data. The copy is scheduled strictly after the original
        // (delay ≥ 1), carries the same dedup key, and is dropped by the
        // matching layer before any matching or wakeup — so it can never
        // be observed out of FIFO order or matched twice.
        let dup = if !rendezvous && tier != Tier::SelfMsg {
            st.faults.as_ref().and_then(|f| f.duplicate(src)).map(|delay| {
                let key = st.next_dup_id.get() + 1;
                st.next_dup_id.set(key);
                st.record_fault(src, dst, fault::FAULT_DUPLICATE, tier, arrival, arrival + delay);
                (key, delay)
            })
        } else {
            None
        };
        let dup_key = dup.map(|(k, _)| k);
        if let Some((key, delay)) = dup {
            let state = st.clone();
            let payload2 = payload.clone();
            let sync2 = sync_req.clone();
            st.sim.schedule(arrival + delay, move || {
                deliver(
                    &state, ctx, src, dst, tag, payload2, rendezvous, sync2, msg_id, Some(key),
                );
            });
        }

        // Schedule the arrival at the destination.
        let state = st.clone();
        st.sim.schedule(arrival, move || {
            deliver(
                &state, ctx, src, dst, tag, payload, rendezvous, sync_req, msg_id, dup_key,
            );
        });
        req
    }

    /// Blocking standard send.
    pub async fn send(&self, dst: usize, tag: Tag, payload: Payload) {
        let r = self.isend(dst, tag, payload).await;
        r.await;
    }

    // -- receives -----------------------------------------------------------

    /// Non-blocking receive. `src`/`tag` accept [`ANY_SOURCE`]/[`ANY_TAG`];
    /// `src` is comm-local. Matching keys on (ctx, src, tag), so even a
    /// double wildcard only sees this communicator's traffic.
    pub async fn irecv(&self, src: usize, tag: Tag) -> Request {
        let st = &self.state;
        let src = self.to_world(src);
        let ctx = self.ctx;
        // One indexed lookup yields both the candidate match and the
        // charged scan count (the arrival-order position a linear scan of
        // the queue would stop at — the modeled queue-search cost).
        let (cand, scanned, epoch) = {
            let r = st.ranks[self.rank].borrow();
            let cand = r.unexpected.first_match(ctx, src, tag);
            (cand, r.unexpected.scanned(cand), r.unexpected.epoch)
        };
        self.charge_cpu(st.cost.match_cost(scanned)).await;

        // Authoritative match *after* the charge: a message may have
        // arrived (or been taken by a sibling task on this rank) while the
        // CPU was busy; matching must observe it, or the receive would be
        // posted while its message rots in the queue. The epoch guard
        // skips the re-lookup in the common unchanged case.
        let found = {
            let mut r = st.ranks[self.rank].borrow_mut();
            let cand = if r.unexpected.epoch == epoch {
                cand
            } else {
                r.unexpected.first_match(ctx, src, tag)
            };
            cand.map(|(pos, _)| r.unexpected.remove_at(pos))
        };
        if let Some(m) = found {
            return self.complete_match(m).await;
        }

        // Post the receive for a future arrival.
        let req = Request::new();
        st.ranks[self.rank]
            .borrow_mut()
            .posted
            .push(ctx, src, tag, req.clone(), self.group.clone());
        req
    }

    /// Matched an unexpected message: produce its (already- or about-to-be-)
    /// completed request, honoring rendezvous data transfer and sync acks.
    async fn complete_match(&self, m: InMsg) -> Request {
        let st = &self.state;
        debug_assert_eq!(m.ctx, self.ctx, "cross-context unexpected match");
        st.tracer.note_ctx_match(m.ctx, self.ctx);
        let now = st.sim.now();
        let tier = st.topo.tier(m.src, self.rank);
        let world_src = m.src;
        let req = Request::new();
        let (bytes, msg_id) = (m.payload.bytes, m.msg_id);
        let msg = Msg {
            src: self.to_local(m.src),
            tag: m.tag,
            payload: m.payload,
        };
        if m.rendezvous {
            // CTS back to the sender, then the data transfer.
            let cts = st.cost.latency[tier as usize];
            let data = st.cost.inject_time(tier, msg.payload.bytes)
                + st.cost.wire_time(tier, msg.payload.bytes);
            let done_at = now + cts + data;
            if st.tracer.enabled() {
                st.tracer.record(Event {
                    kind: EventKind::UnexpectedHit,
                    ctx: m.ctx,
                    rank: self.rank,
                    peer: world_src,
                    tag: msg.tag,
                    bytes,
                    tier,
                    t_start: now,
                    t_end: done_at,
                    msg_id,
                });
            }
            let req2 = req.clone();
            let sync_req = m.sync_req.clone();
            st.sim.schedule(done_at, move || {
                if let Some(s) = &sync_req {
                    s.complete(None);
                }
                req2.complete(Some(msg));
            });
        } else {
            if st.tracer.enabled() {
                st.tracer.record(Event {
                    kind: EventKind::UnexpectedHit,
                    ctx: m.ctx,
                    rank: self.rank,
                    peer: world_src,
                    tag: msg.tag,
                    bytes,
                    tier,
                    t_start: now,
                    t_end: now,
                    msg_id,
                });
            }
            if let Some(s) = &m.sync_req {
                // Ack travels back one latency.
                let s = s.clone();
                st.sim
                    .schedule(now + st.cost.latency[tier as usize], move || {
                        s.complete(None)
                    });
            }
            req.complete(Some(msg));
        }
        req
    }

    /// Blocking receive.
    pub async fn recv(&self, src: usize, tag: Tag) -> Msg {
        let req = self.irecv(src, tag).await;
        req.await.expect("recv request produced no message")
    }

    // -- probes -------------------------------------------------------------

    /// Non-blocking probe: one indexed lookup (charging the modeled
    /// queue-search cost of the scan it stands in for) reporting a
    /// matching envelope if present. An empty or missed queue charges the
    /// whole-queue scan and touches no entries on the host.
    pub async fn iprobe(&self, src: usize, tag: Tag) -> Option<ProbeInfo> {
        let st = &self.state;
        let src = self.to_world(src);
        let (info, scanned) = {
            let r = st.ranks[self.rank].borrow();
            let cand = r.unexpected.first_match(self.ctx, src, tag);
            let info = cand.map(|(pos, _)| {
                let m = r.unexpected.peek(pos);
                ProbeInfo {
                    src: self.to_local(m.src),
                    tag: m.tag,
                    count: m.payload.len(),
                    bytes: m.payload.bytes,
                }
            });
            (info, r.unexpected.scanned(cand))
        };
        self.charge_cpu(st.cost.match_cost(scanned)).await;
        info
    }

    /// Blocking probe: wait until a matching message is available without
    /// consuming it.
    pub async fn probe(&self, src: usize, tag: Tag) -> ProbeInfo {
        // Hang diagnosis: the probe is a blocked op until it returns (the
        // guard unregisters on drop, even across cancellation).
        let _guard = OpGuard::register(
            &self.state,
            self.rank,
            BlockedOp {
                kind: OpKind::Probe,
                ctx: self.ctx,
                peer: self.to_world(src),
                tag,
                since: Some(self.now()),
            },
        );
        loop {
            // Record the arrival epoch *before* scanning: anything arriving
            // during the scan's CPU charge bumps it and re-triggers a scan.
            let epoch = self.state.ranks[self.rank].borrow().arrival_epoch;
            if let Some(info) = self.iprobe(src, tag).await {
                return info;
            }
            ArrivalWait::at_epoch(self, epoch).await;
        }
    }

    /// Dynamic receive à la `MPI_Probe` + `MPI_Recv` of the probed message.
    pub async fn probe_recv(&self, src: usize, tag: Tag) -> Msg {
        let info = self.probe(src, tag).await;
        self.recv(info.src, info.tag).await
    }

    /// Reserve and return the next sequence number for an internal
    /// collective tag family (all ranks call collectives in the same
    /// order, so sequence numbers agree). Per-communicator state: comms
    /// produced by [`Comm::dup`]/[`Comm::split`] start fresh and never
    /// interleave with their parent's sequences.
    pub(crate) fn next_seq(&self, family: Tag) -> u32 {
        let mut seqs = self.comm_state.seqs.borrow_mut();
        let seq = seqs.entry(family).or_insert(0);
        let s = *seq;
        *seq = seq.wrapping_add(1);
        s
    }

    /// Current arrival epoch of this rank (bumps on every delivery).
    pub fn arrival_epoch(&self) -> u64 {
        self.state.ranks[self.rank].borrow().arrival_epoch
    }

    /// Register a waker for the next arrival at this rank. Re-registering
    /// the same task before the next arrival is deduplicated.
    pub fn register_arrival_waker(&self, waker: &Waker) {
        let mut r = self.state.ranks[self.rank].borrow_mut();
        if !r.arrival_wakers.iter().any(|w| w.will_wake(waker)) {
            r.arrival_wakers.push(waker.clone());
        }
    }

    /// Counters snapshot (shared across ranks; callers usually read it from
    /// [`RunOutput`] instead).
    pub fn counters(&self) -> Counters {
        self.state.counters.borrow().clone()
    }

    pub(crate) fn bump_counter(&self, f: impl FnOnce(&mut Counters)) {
        f(&mut self.state.counters.borrow_mut());
    }

    /// Snapshot of the trace rollup counters so far (empty when tracing is
    /// disabled; callers usually read the final one from [`RunOutput`]).
    pub fn trace_summary(&self) -> TraceSummary {
        self.state.tracer.summary_snapshot()
    }

    /// Trace-derived count of *user* inter-node messages injected by `rank`
    /// so far. Mirrors `Counters::internode_sent` bit for bit when tracing
    /// is enabled; always 0 when disabled.
    pub fn traced_internode_sent(&self, rank: usize) -> u64 {
        self.state.tracer.internode_sent(rank)
    }

    /// Trace helper for the collective layer: record one algorithm round
    /// (partner exchange) spanning `[t_start, now]`. `peer` is comm-local.
    /// No-op when disabled.
    pub(crate) fn trace_coll_round(&self, peer: usize, tag: Tag, bytes: usize, t_start: Time) {
        if self.state.tracer.enabled() {
            let peer = self.to_world(peer);
            let tier = self.state.topo.tier(self.rank, peer);
            self.state.tracer.record(Event {
                kind: EventKind::CollRound,
                ctx: self.ctx,
                rank: self.rank,
                peer,
                tag,
                bytes,
                tier,
                t_start,
                t_end: self.state.sim.now(),
                msg_id: 0,
            });
        }
    }
}

/// Arrival delivery: match against posted receives or append to the
/// unexpected queue; wake probe waiters. `dup_key` marks deliveries that
/// fault injection may retransmit: the matching layer keeps the first
/// copy and silently drops the rest *before* any matching or wakeup.
#[allow(clippy::too_many_arguments)]
fn deliver(
    state: &Rc<WorldState>,
    ctx: CtxId,
    src: usize,
    dst: usize,
    tag: Tag,
    payload: Payload,
    rendezvous: bool,
    sync_req: Option<Request>,
    msg_id: u64,
    dup_key: Option<u64>,
) {
    if let Some(key) = dup_key {
        let mut r = state.ranks[dst].borrow_mut();
        if !r.seen_dups.insert((ctx, key)) {
            // Retransmitted copy: already delivered once. Dropping here —
            // before the epoch bump, matching, and wakes — makes the
            // duplicate invisible to every observable queue state.
            return;
        }
    }
    // Deliveries are the watchdog's notion of forward progress.
    state.sim.note_progress();
    let mut r = state.ranks[dst].borrow_mut();
    r.arrival_epoch += 1;
    // Drain arrival wakers into the reusable scratch buffer (no per-message
    // Vec allocation; restored at the end of the function).
    let mut wakers = std::mem::take(&mut r.wakers_scratch);
    debug_assert!(wakers.is_empty());
    wakers.append(&mut r.arrival_wakers);

    // Match against posted receives, in post order (bucketed lookup; the
    // charged cost below is the post-order position, as before — the queue
    // is shared across communicators, like a real MPI matching engine, so
    // the charged scan depth is the *global* post-order position).
    if let Some(i) = r.posted.first_match(ctx, src, tag) {
        let spec = r.posted.remove_at(i);
        debug_assert_eq!(spec.ctx, ctx, "cross-context posted match");
        state.tracer.note_ctx_match(ctx, spec.ctx);
        // Charge the receiver's CPU for the match.
        let now = state.sim.now();
        let scanned = i + 1;
        let mcost = state.cost.match_cost(scanned);
        r.cpu_free = r.cpu_free.max(now) + mcost;
        let tier = state.topo.tier(src, dst);
        let bytes = payload.bytes;
        // Msg.src is communicator-local; events below keep the world rank.
        let msg = Msg {
            src: spec.local_src(src),
            tag,
            payload,
        };
        if rendezvous {
            let cts = state.cost.latency[tier as usize];
            let data = state.cost.inject_time(tier, msg.payload.bytes)
                + state.cost.wire_time(tier, msg.payload.bytes);
            let done_at = now + mcost + cts + data;
            drop(r);
            record_recv_match(state, ctx, dst, src, tag, bytes, tier, now, done_at, msg_id);
            let req = spec.req;
            state.sim.schedule(done_at, move || {
                if let Some(s) = &sync_req {
                    s.complete(None);
                }
                req.complete(Some(msg));
            });
        } else {
            if let Some(s) = &sync_req {
                let s = s.clone();
                state
                    .sim
                    .schedule(now + state.cost.latency[tier as usize], move || {
                        s.complete(None)
                    });
            }
            drop(r);
            record_recv_match(state, ctx, dst, src, tag, bytes, tier, now, now + mcost, msg_id);
            spec.req.complete(Some(msg));
        }
    } else {
        r.unexpected.push(InMsg {
            ctx,
            src,
            tag,
            payload,
            rendezvous,
            sync_req,
            msg_id,
            seq: 0, // assigned by push
        });
        drop(r);
    }
    for w in wakers.drain(..) {
        w.wake();
    }
    // Hand the (empty, capacity-retaining) buffer back for the next
    // delivery. Wakes only enqueue tasks on this executor, so nothing ran
    // in between that could have taken the scratch buffer.
    state.ranks[dst].borrow_mut().wakers_scratch = wakers;
}

/// Trace helper: one posted-receive match event (no-op when disabled).
/// `src` is the sender's world rank (events always use world ranks).
#[allow(clippy::too_many_arguments)]
fn record_recv_match(
    state: &Rc<WorldState>,
    ctx: CtxId,
    dst: usize,
    src: usize,
    tag: Tag,
    bytes: usize,
    tier: Tier,
    t_start: Time,
    t_end: Time,
    msg_id: u64,
) {
    if state.tracer.enabled() {
        state.tracer.record(Event {
            kind: EventKind::RecvMatch,
            ctx,
            rank: dst,
            peer: src,
            tag,
            bytes,
            tier,
            t_start,
            t_end,
            msg_id,
        });
    }
}

/// Future that completes on the next message arrival at `rank` (used by
/// blocking probe).
struct ArrivalWait {
    state: Rc<WorldState>,
    rank: usize,
    epoch: u64,
}

impl ArrivalWait {
    /// Completes once the rank's arrival epoch differs from `epoch`
    /// (i.e. at least one arrival happened after the caller sampled it).
    fn at_epoch(comm: &Comm, epoch: u64) -> ArrivalWait {
        ArrivalWait {
            state: comm.state.clone(),
            rank: comm.rank,
            epoch,
        }
    }
}

impl Future for ArrivalWait {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let mut r = self.state.ranks[self.rank].borrow_mut();
        if r.arrival_epoch != self.epoch {
            Poll::Ready(())
        } else {
            let waker = cx.waker();
            if !r.arrival_wakers.iter().any(|w| w.will_wake(waker)) {
                r.arrival_wakers.push(waker.clone());
            }
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::MpiFlavor;

    fn world(nodes: usize, ppn: usize) -> World {
        World::new(
            Topology::quartz(nodes, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
    }

    #[test]
    fn ping_message() {
        // ppn=4 → 2 ranks per socket; 0→1 is intra-socket.
        let out = world(1, 4).run(|c| async move {
            match c.rank() {
                0 => {
                    c.send(1, 7, Payload::ints(&[42])).await;
                    0
                }
                1 => {
                    let m = c.recv(0, 7).await;
                    assert_eq!(m.src, 0);
                    assert_eq!(m.payload.words, vec![42]);
                    m.payload.words[0]
                }
                _ => 0,
            }
        });
        assert_eq!(out.results, vec![0, 42, 0, 0]);
        assert!(out.end_time > 0);
        assert_eq!(out.counters.user_msgs[Tier::IntraSocket as usize], 1);
    }

    #[test]
    fn wildcard_recv_and_probe() {
        let out = world(1, 3).run(|c| async move {
            match c.rank() {
                0 => {
                    c.send(2, 5, Payload::ints(&[1, 2, 3])).await;
                    Vec::new()
                }
                1 => {
                    c.send(2, 5, Payload::ints(&[9])).await;
                    Vec::new()
                }
                _ => {
                    let mut got = Vec::new();
                    for _ in 0..2 {
                        let info = c.probe(ANY_SOURCE, 5).await;
                        let m = c.recv(info.src, info.tag).await;
                        assert_eq!(m.payload.len(), info.count);
                        got.push((m.src, m.payload.words.len()));
                    }
                    got.sort_unstable();
                    got
                }
            }
        });
        assert_eq!(out.results[2], vec![(0, 3), (1, 1)]);
    }

    #[test]
    fn issend_completes_only_after_match() {
        // Receiver delays before receiving; the sync-send request must not
        // complete before the receiver's recv call.
        let out = world(2, 1).run(|c| async move {
            if c.rank() == 0 {
                let req = c.issend(1, 3, Payload::ints(&[5])).await;
                let mut spins = 0u64;
                while !req.is_done() {
                    spins += 1;
                    c.charge_cpu(100).await;
                }
                assert!(spins > 10, "sync send completed suspiciously early");
                c.now()
            } else {
                c.sim().sleep(50_000).await;
                let m = c.recv(0, 3).await;
                assert_eq!(m.payload.words, vec![5]);
                c.now()
            }
        });
        // Sender finished after receiver matched (within an ack latency).
        assert!(out.results[0] >= 50_000);
    }

    #[test]
    fn eager_isend_completes_locally() {
        let out = world(2, 1).run(|c| async move {
            if c.rank() == 0 {
                let req = c.isend(1, 3, Payload::ints(&[5])).await;
                req.await;
                let t_send_done = c.now();
                assert!(t_send_done < 50_000, "eager send blocked on receiver");
                t_send_done
            } else {
                c.sim().sleep(50_000).await;
                c.recv(0, 3).await;
                c.now()
            }
        });
        assert!(out.results[1] >= 50_000);
    }

    #[test]
    fn rendezvous_large_message() {
        let big = vec![1u64; 10_000]; // 80 KB > eager limit
        let out = world(2, 1).run(move |c| {
            let big = big.clone();
            async move {
                if c.rank() == 0 {
                    let req = c.isend(1, 9, Payload::longs(&big)).await;
                    req.await; // rendezvous send completes only after pull
                    c.now()
                } else {
                    c.sim().sleep(10_000).await;
                    let m = c.recv(0, 9).await;
                    assert_eq!(m.payload.words.len(), 10_000);
                    c.now()
                }
            }
        });
        // Sender completion awaited the receiver's match.
        assert!(out.results[0] >= 10_000);
    }

    #[test]
    fn fifo_per_pair() {
        let out = world(1, 2).run(|c| async move {
            if c.rank() == 0 {
                for i in 0..20u64 {
                    c.isend(1, 1, Payload::ints(&[i])).await;
                }
                Vec::new()
            } else {
                let mut got = Vec::new();
                for _ in 0..20 {
                    got.push(c.recv(0, 1).await.payload.words[0]);
                }
                got
            }
        });
        assert_eq!(out.results[1], (0..20).collect::<Vec<u64>>());
    }

    #[test]
    fn iprobe_returns_none_when_empty() {
        let out = world(1, 1).run(|c| async move { c.iprobe(ANY_SOURCE, ANY_TAG).await });
        assert!(out.results[0].is_none());
    }

    #[test]
    fn self_send() {
        let out = world(1, 1).run(|c| async move {
            c.isend(0, 2, Payload::ints(&[11])).await;
            c.recv(0, 2).await.payload.words[0]
        });
        assert_eq!(out.results[0], vec![11][0]);
    }

    #[test]
    fn internode_costs_more_than_intranode() {
        let t_intra = world(1, 2)
            .run(|c| async move {
                if c.rank() == 0 {
                    c.send(1, 1, Payload::ints(&[1])).await;
                } else {
                    c.recv(0, 1).await;
                }
            })
            .end_time;
        let t_inter = world(2, 1)
            .run(|c| async move {
                if c.rank() == 0 {
                    c.send(1, 1, Payload::ints(&[1])).await;
                } else {
                    c.recv(0, 1).await;
                }
            })
            .end_time;
        assert!(t_inter > t_intra, "inter={t_inter} intra={t_intra}");
    }

    #[test]
    fn internode_counter_tracks_sender() {
        let out = world(2, 2).run(|c| async move {
            if c.rank() == 0 {
                c.send(2, 1, Payload::ints(&[1])).await;
                c.send(3, 1, Payload::ints(&[1])).await;
                c.send(1, 1, Payload::ints(&[1])).await; // intra-node
            } else if c.rank() == 1 {
                c.recv(0, 1).await;
            } else {
                c.recv(0, 1).await;
            }
        });
        assert_eq!(out.counters.internode_sent[0], 2);
        assert_eq!(out.counters.max_internode_per_rank(), 2);
    }

    #[test]
    fn any_tag_recv_gets_earliest_from_source() {
        // Exercises the by-src bucket: (concrete src, ANY_TAG) receives
        // must drain that source's messages in arrival (FIFO) order.
        let out = world(1, 3).run(|c| async move {
            match c.rank() {
                0 => {
                    for t in [7u32, 3, 9] {
                        c.send(2, t, Payload::ints(&[t as u64])).await;
                    }
                    vec![]
                }
                1 => {
                    c.send(2, 1, Payload::ints(&[100])).await;
                    vec![]
                }
                _ => {
                    c.sim().sleep(1_000_000).await; // let everything queue up
                    let mut got = Vec::new();
                    for _ in 0..3 {
                        got.push(c.recv(0, ANY_TAG).await.payload.words[0]);
                    }
                    got.push(c.recv(1, ANY_TAG).await.payload.words[0]);
                    got
                }
            }
        });
        assert_eq!(out.results[2], vec![7, 3, 9, 100]);
    }

    #[test]
    fn posted_wildcard_first_posted_wins() {
        // Matching against posted receives is in post order: a wildcard
        // posted before an exact spec takes the first arrival.
        let out = world(1, 2).run(|c| async move {
            if c.rank() == 0 {
                c.sim().sleep(10_000).await;
                c.send(1, 5, Payload::ints(&[1])).await;
                c.send(1, 5, Payload::ints(&[2])).await;
                0
            } else {
                let r_any = c.irecv(ANY_SOURCE, ANY_TAG).await;
                let r_exact = c.irecv(0, 5).await;
                let m_any = r_any.await.unwrap();
                let m_exact = r_exact.await.unwrap();
                m_any.payload.words[0] * 10 + m_exact.payload.words[0]
            }
        });
        assert_eq!(out.results[1], 12);
    }

    #[test]
    fn deep_queue_distinct_tags_match_from_any_position() {
        // 300 distinct tags queued, drained in reverse order: every recv
        // matches at a different arrival-order position, and the per-tag
        // buckets are created and torn down along the way.
        let out = world(1, 2).run(|c| async move {
            if c.rank() == 0 {
                for t in 0..300u32 {
                    c.isend(1, t, Payload::ints(&[t as u64])).await;
                }
                0
            } else {
                c.sim().sleep(5_000_000).await;
                let mut sum = 0u64;
                for t in (0..300u32).rev() {
                    sum += c.recv(0, t).await.payload.words[0];
                }
                sum
            }
        });
        assert_eq!(out.results[1], (0..300u64).sum::<u64>());
    }

    #[test]
    fn host_stats_populated() {
        let out = world(1, 2).run(|c| async move {
            if c.rank() == 0 {
                c.send(1, 1, Payload::ints(&[1])).await;
            } else {
                c.recv(0, 1).await;
            }
        });
        assert!(out.exec_stats.events_run > 0);
        assert!(out.exec_stats.polls > 0);
        // Wall-clock accounting: Instant is monotonic and the run did real
        // work, so a populated (possibly small) duration must be recorded.
        assert!(out.exec_stats.host_ns > 0);
    }

    #[test]
    fn deterministic_end_time() {
        let run = || {
            world(2, 4).run(|c| async move {
                let n = c.nranks();
                let me = c.rank();
                // everyone sends to everyone
                let mut reqs = Vec::new();
                for d in 0..n {
                    if d != me {
                        reqs.push(c.isend(d, 1, Payload::ints(&[me as u64])).await);
                    }
                }
                let mut sum = 0u64;
                for _ in 0..n - 1 {
                    sum += c.probe_recv(ANY_SOURCE, 1).await.payload.words[0];
                }
                waitall(&reqs).await;
                sum
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.results, b.results);
        let expect: u64 = (0..8).sum();
        for (me, s) in a.results.iter().enumerate() {
            assert_eq!(*s, expect - me as u64);
        }
    }

    // -- fault injection / hang diagnosis ------------------------------------

    use crate::simnet::FaultProfile;

    fn all_to_all_prog(c: Comm) -> impl Future<Output = u64> {
        async move {
            let n = c.nranks();
            let me = c.rank();
            let mut reqs = Vec::new();
            for d in 0..n {
                if d != me {
                    reqs.push(c.isend(d, 1, Payload::ints(&[me as u64])).await);
                }
            }
            let mut sum = 0u64;
            for _ in 0..n - 1 {
                sum += c.probe_recv(ANY_SOURCE, 1).await.payload.words[0];
            }
            waitall(&reqs).await;
            sum
        }
    }

    #[test]
    fn off_fault_plan_is_bit_identical() {
        // The inactive plan must not allocate fault state, draw RNG, or
        // perturb a single virtual timestamp.
        let base = world(2, 4).run(all_to_all_prog);
        let off = World::builder(
            Topology::quartz(2, 4),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
        .faults(Some(FaultPlan::off()))
        .build()
        .run(all_to_all_prog);
        assert_eq!(base.end_time, off.end_time);
        assert_eq!(base.results, off.results);
        assert_eq!(base.counters, off.counters);
        assert_eq!(base.exec_stats.events_run, off.exec_stats.events_run);
        assert_eq!(base.exec_stats.polls, off.exec_stats.polls);
    }

    #[test]
    fn faulted_world_preserves_results_and_traffic() {
        let base = world(2, 4).run(all_to_all_prog);
        let plan = FaultPlan::with_profile(7, FaultProfile::heavy());
        let faulted = World::builder(
            Topology::quartz(2, 4),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
        .faults(Some(plan))
        .build()
        .run(all_to_all_prog);
        // Perturbations reorder and delay, but never corrupt or duplicate:
        // delivered data and injection-time traffic counters are invariant.
        assert_eq!(base.results, faulted.results);
        assert_eq!(base.counters, faulted.counters);
    }

    #[test]
    fn faulted_world_is_deterministic_per_seed() {
        let plan = FaultPlan::with_profile(3, FaultProfile::heavy());
        let run = || {
            World::builder(
                Topology::quartz(2, 4),
                CostModel::preset(MpiFlavor::Mvapich2),
            )
            .faults(Some(plan))
            .build()
            .run(all_to_all_prog)
        };
        let a = run();
        let b = run();
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn duplicate_delivery_is_deduped() {
        // Aggressive duplication: the receiver must still see exactly one
        // copy of each message, in FIFO order, with nothing left queued.
        let plan = FaultPlan::with_profile(5, FaultProfile::duplicate());
        let out = World::builder(
            Topology::quartz(2, 1),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
        .faults(Some(plan))
        .build()
        .run(|c| async move {
            if c.rank() == 0 {
                for i in 0..40u64 {
                    c.isend(1, 1, Payload::ints(&[i])).await;
                }
                Vec::new()
            } else {
                let mut got = Vec::new();
                for _ in 0..40 {
                    got.push(c.recv(0, 1).await.payload.words[0]);
                }
                // Let any trailing retransmits land (and be dropped).
                c.sim().sleep(10_000_000).await;
                assert!(c.iprobe(ANY_SOURCE, ANY_TAG).await.is_none());
                got
            }
        });
        assert_eq!(out.results[1], (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn forced_rendezvous_keeps_send_semantics() {
        // Every eager-eligible send is forced down the rendezvous path:
        // content still arrives intact and isend completes after the match.
        let plan = FaultPlan::with_profile(1, FaultProfile::rendezvous());
        let out = World::builder(
            Topology::quartz(2, 1),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
        .faults(Some(plan))
        .build()
        .run(|c| async move {
            if c.rank() == 0 {
                let req = c.isend(1, 3, Payload::ints(&[5])).await;
                req.await;
                c.now()
            } else {
                c.sim().sleep(50_000).await;
                let m = c.recv(0, 3).await;
                assert_eq!(m.payload.words, vec![5]);
                c.now()
            }
        });
        // Forced-rendezvous completion awaited the receiver's match.
        assert!(out.results[0] >= 50_000);
    }

    #[test]
    fn run_checked_reports_mismatched_tag() {
        let res = world(2, 1).run_checked(|c| async move {
            if c.rank() == 0 {
                c.isend(1, 7, Payload::ints(&[1])).await;
            } else {
                c.recv(0, 8).await; // wrong tag: hangs
            }
        });
        let wg = res.err().expect("expected a stalled world");
        assert_eq!(wg.blocked_ranks(), vec![1]);
        let ops = wg.ops_of(1);
        assert_eq!(ops.len(), 1);
        assert_eq!(ops[0].kind, super::super::watchdog::OpKind::Recv);
        assert_eq!((ops[0].peer, ops[0].tag), (0, 8));
        let b = &wg.blocked[0];
        assert_eq!(b.near_misses.len(), 1);
        assert_eq!((b.near_misses[0].src, b.near_misses[0].tag), (0, 7));
        assert_eq!(
            b.near_misses[0].reason,
            super::super::watchdog::MissReason::TagMismatch
        );
        assert!(wg.cycle.is_none());
        assert!(wg.render().contains("near miss"));
    }

    #[test]
    #[should_panic(expected = "simulation deadlock")]
    fn run_panics_with_wait_graph() {
        world(2, 1).run(|c| async move {
            if c.rank() == 1 {
                c.recv(0, 1).await; // no matching send anywhere
            }
        });
    }

    #[test]
    fn dup_comms_isolate_matching() {
        // Same (src, tag) in flight on two communicators: each recv must
        // match only its own communicator's message, even when the "wrong"
        // one is already sitting in the unexpected queue.
        let out = world(2, 1).run(|c| async move {
            let a = c.dup().await;
            let b = c.dup().await;
            if c.rank() == 0 {
                b.send(1, 7, Payload::ints(&[200])).await;
                a.send(1, 7, Payload::ints(&[100])).await;
                Vec::new()
            } else {
                let ma = a.recv(0, 7).await;
                let mb = b.recv(0, 7).await;
                vec![ma.payload.words[0], mb.payload.words[0]]
            }
        });
        assert_eq!(out.results[1], vec![100, 200]);
    }

    #[test]
    fn split_renumbers_and_translates_ranks() {
        // Odd/even split ordered by *descending* world rank (key = -rank):
        // rank translation must hold on both the send and recv paths, and
        // Msg.src must come back comm-local.
        let out = world(1, 4).run(|c| async move {
            let sub = c.split((c.rank() % 2) as u64, -(c.rank() as i64)).await;
            let peer = (sub.rank() + 1) % sub.nranks();
            sub.send(peer, 3, Payload::ints(&[c.rank() as u64])).await;
            let m = sub.recv(ANY_SOURCE, 3).await;
            (sub.rank(), sub.nranks(), m.src, m.payload.words[0])
        });
        // Evens {0,2} become sub ranks {1,0}; odds {1,3} become {1,0}.
        assert_eq!(out.results[0], (1, 2, 0, 2));
        assert_eq!(out.results[2], (0, 2, 1, 0));
        assert_eq!(out.results[1], (1, 2, 0, 3));
        assert_eq!(out.results[3], (0, 2, 1, 1));
    }

    #[test]
    fn next_seq_is_per_communicator() {
        // Tag sequencing is per-(rank, communicator): dup'd comms start
        // fresh and advance independently of their parent and each other.
        let out = world(1, 1).run(|c| async move {
            let a = c.dup().await;
            let b = c.dup().await;
            let s0 = (c.next_seq(42), c.next_seq(42));
            let sa = (a.next_seq(42), a.next_seq(42));
            let sb = (b.next_seq(42), b.next_seq(42));
            (s0, sa, sb)
        });
        assert_eq!(out.results[0], ((0, 1), (0, 1), (0, 1)));
    }

    #[test]
    fn run_checked_reports_ctx_mismatch() {
        // The classic multi-communicator bug: right (src, tag), wrong
        // communicator. The wait graph must name the context mismatch.
        let res = world(2, 1).run_checked(|c| async move {
            let a = c.dup().await;
            let b = c.dup().await;
            if c.rank() == 0 {
                a.isend(1, 7, Payload::ints(&[1])).await;
            } else {
                b.recv(0, 7).await; // hangs: message lives on comm `a`
            }
        });
        let wg = res.err().expect("expected a stalled world");
        assert_eq!(wg.blocked_ranks(), vec![1]);
        let b = &wg.blocked[0];
        assert_eq!(b.near_misses.len(), 1);
        let nm = &b.near_misses[0];
        assert_eq!(
            nm.reason,
            super::super::watchdog::MissReason::CtxMismatch
        );
        assert_eq!((nm.src, nm.tag), (0, 7));
        assert_eq!((nm.ctx, nm.wanted_ctx), (CtxId(1), CtxId(2)));
        let rendered = wg.render();
        assert!(rendered.contains("context mismatch"));
        assert!(rendered.contains("on ctx 2"));
    }
}
