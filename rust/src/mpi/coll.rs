//! Collectives built from the simulated p2p layer, so their cost *emerges*
//! from the same network model the SDDE algorithms pay (latency, injection,
//! matching): allreduce (recursive doubling with a non-power-of-two fold),
//! blocking barrier, non-blocking barrier (dissemination, progressed by a
//! background task — the shape NBX needs), broadcast, gather/allgather and
//! dense alltoall(v) for the intra-region redistribution ablation.

use super::wait::Signal;
use super::world::{Comm, Msg, Payload};
use super::{Tag, TAG_ALLREDUCE, TAG_ALLTOALL, TAG_BARRIER, TAG_BCAST, TAG_GATHER, TAG_IBARRIER};

/// Reduction operator for [`Comm::allreduce`]. `FSum`/`FMax` treat the
/// words as bit-cast `f64` (used by the distributed solvers' dot products).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    FSum,
    FMax,
}

impl ReduceOp {
    fn apply(&self, acc: &mut [u64], other: &[u64]) {
        debug_assert_eq!(acc.len(), other.len());
        match self {
            ReduceOp::Sum => {
                for (a, b) in acc.iter_mut().zip(other) {
                    *a = a.wrapping_add(*b);
                }
            }
            ReduceOp::Max => {
                for (a, b) in acc.iter_mut().zip(other) {
                    *a = (*a).max(*b);
                }
            }
            ReduceOp::FSum => {
                for (a, b) in acc.iter_mut().zip(other) {
                    *a = (f64::from_bits(*a) + f64::from_bits(*b)).to_bits();
                }
            }
            ReduceOp::FMax => {
                for (a, b) in acc.iter_mut().zip(other) {
                    *a = f64::from_bits(*a).max(f64::from_bits(*b)).to_bits();
                }
            }
        }
    }
}

/// Tag for collective `family` at sequence `seq` (wraps harmlessly: only
/// nearby collectives must be distinguishable).
fn coll_tag(family: Tag, seq: u32, round: u32) -> Tag {
    family + ((seq % 0x1000) << 8) + round
}

impl Comm {
    /// MPI_Allreduce over a `u64` vector (recursive doubling; fold step for
    /// non-power-of-two rank counts). Every rank gets the reduced vector.
    pub async fn allreduce(&self, mut vec: Vec<u64>, op: ReduceOp) -> Vec<u64> {
        let n = self.nranks();
        let me = self.rank();
        if me == 0 {
            self.bump_counter(|c| c.allreduces += 1);
        }
        if n == 1 {
            return vec;
        }
        let seq = self.next_seq(TAG_ALLREDUCE);
        let elem_cost = self.cost().reduce_per_elem * vec.len() as u64;
        let m = n.next_power_of_two() >> if n.is_power_of_two() { 0 } else { 1 };
        let rem = n - m; // ranks beyond the largest power of two

        // Fold: ranks >= m send their vector to (rank - m); those partners
        // reduce locally.
        if me >= m {
            let tag = coll_tag(TAG_ALLREDUCE, seq, 50);
            self.send(me - m, tag, Payload::longs(&vec)).await;
        } else if me < rem {
            let tag = coll_tag(TAG_ALLREDUCE, seq, 50);
            let msg = self.recv(me + m, tag).await;
            op.apply(&mut vec, &msg.payload.words);
            self.charge_cpu(elem_cost).await;
        }

        // Recursive doubling among ranks < m.
        if me < m {
            let mut dist = 1usize;
            let mut round = 0u32;
            while dist < m {
                let partner = me ^ dist;
                let tag = coll_tag(TAG_ALLREDUCE, seq, round);
                let t0 = self.now();
                let sreq = self.isend(partner, tag, Payload::longs(&vec)).await;
                let msg = self.recv(partner, tag).await;
                op.apply(&mut vec, &msg.payload.words);
                self.charge_cpu(elem_cost).await;
                sreq.await;
                self.trace_coll_round(partner, tag, 8 * vec.len(), t0);
                dist <<= 1;
                round += 1;
            }
        }

        // Unfold: partners send the result back to ranks >= m.
        if me < rem {
            let tag = coll_tag(TAG_ALLREDUCE, seq, 60);
            self.send(me + m, tag, Payload::longs(&vec)).await;
        } else if me >= m {
            let tag = coll_tag(TAG_ALLREDUCE, seq, 60);
            vec = self.recv(me - m, tag).await.payload.words;
        }
        vec
    }

    /// Blocking barrier (dissemination algorithm).
    pub async fn barrier(&self) {
        let n = self.nranks();
        if n == 1 {
            return;
        }
        let me = self.rank();
        let seq = self.next_seq(TAG_BARRIER);
        let mut dist = 1usize;
        let mut round = 0u32;
        while dist < n {
            let to = (me + dist) % n;
            let from = (me + n - dist % n) % n;
            let tag = coll_tag(TAG_BARRIER, seq, round);
            let t0 = self.now();
            let sreq = self.isend(to, tag, Payload::empty()).await;
            self.recv(from, tag).await;
            sreq.await;
            self.trace_coll_round(to, tag, 0, t0);
            dist <<= 1;
            round += 1;
        }
    }

    /// Non-blocking barrier (MPI_Ibarrier): returns a handle whose
    /// [`IBarrier::is_done`] flips once every rank has entered the barrier.
    /// A background task progresses the dissemination rounds so the caller
    /// can interleave probing — exactly the NBX control flow.
    pub async fn ibarrier(&self) -> IBarrier {
        let n = self.nranks();
        let seq = self.next_seq(TAG_IBARRIER);
        let bar = IBarrier {
            sig: Signal::new(),
        };
        if n == 1 {
            bar.sig.set();
            return bar;
        }
        let me = self.rank();
        let comm = self.clone();
        let handle = bar.clone();
        self.sim().spawn(async move {
            let mut dist = 1usize;
            let mut round = 0u32;
            while dist < n {
                let to = (me + dist) % n;
                let from = (me + n - dist % n) % n;
                let tag = coll_tag(TAG_IBARRIER, seq, round);
                let t0 = comm.now();
                let sreq = comm.isend(to, tag, Payload::empty()).await;
                comm.recv(from, tag).await;
                sreq.await;
                comm.trace_coll_round(to, tag, 0, t0);
                dist <<= 1;
                round += 1;
            }
            handle.sig.set();
        });
        bar
    }

    /// Broadcast from `root` (binomial tree).
    pub async fn bcast(&self, root: usize, vec: Vec<u64>) -> Vec<u64> {
        let n = self.nranks();
        if n == 1 {
            return vec;
        }
        let me = self.rank();
        let seq = self.next_seq(TAG_BCAST);
        let tag = coll_tag(TAG_BCAST, seq, 0);
        let vrank = (me + n - root) % n; // virtual rank with root at 0
        let mut data = vec;
        // Receive from parent (for non-root ranks).
        if vrank != 0 {
            let mut mask = 1usize;
            while mask < n {
                if vrank & mask != 0 {
                    let parent = ((vrank ^ mask) + root) % n;
                    data = self.recv(parent, tag).await.payload.words;
                    break;
                }
                mask <<= 1;
            }
        }
        // Forward to children.
        let mut mask = n.next_power_of_two() >> 1;
        while mask > 0 {
            if vrank & (mask - 1) == 0 && vrank & mask == 0 {
                let child = vrank | mask;
                if child < n {
                    let dst = (child + root) % n;
                    self.send(dst, tag, Payload::longs(&data)).await;
                }
            }
            mask >>= 1;
        }
        data
    }

    /// Gather one vector per rank at `root`; returns `Some(vecs)` at root.
    pub async fn gather(&self, root: usize, vec: Vec<u64>) -> Option<Vec<Vec<u64>>> {
        let n = self.nranks();
        let me = self.rank();
        let seq = self.next_seq(TAG_GATHER);
        let tag = coll_tag(TAG_GATHER, seq, 0);
        if me == root {
            let mut out: Vec<Vec<u64>> = vec![Vec::new(); n];
            out[me] = vec;
            for _ in 0..n - 1 {
                let m: Msg = self.probe_recv(super::ANY_SOURCE, tag).await;
                out[m.src] = m.payload.words;
            }
            Some(out)
        } else {
            self.send(root, tag, Payload::longs(&vec)).await;
            None
        }
    }

    /// Dense personalized all-to-all of variable vectors (`sendbufs[d]` goes
    /// to rank `d`). Used by the intra-region redistribution ablation.
    pub async fn alltoallv(&self, sendbufs: Vec<Vec<u64>>) -> Vec<Vec<u64>> {
        let n = self.nranks();
        assert_eq!(sendbufs.len(), n);
        let me = self.rank();
        let seq = self.next_seq(TAG_ALLTOALL);
        let tag = coll_tag(TAG_ALLTOALL, seq, 0);
        let mut out: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut reqs = Vec::new();
        for off in 1..n {
            let dst = (me + off) % n;
            reqs.push(self.isend(dst, tag, Payload::longs(&sendbufs[dst])).await);
        }
        out[me] = sendbufs[me].clone();
        for _ in 0..n - 1 {
            let m = self.probe_recv(super::ANY_SOURCE, tag).await;
            out[m.src] = m.payload.words;
        }
        super::world::waitall(&reqs).await;
        out
    }
}

/// Handle returned by [`Comm::ibarrier`].
#[derive(Clone)]
pub struct IBarrier {
    sig: Signal,
}

impl IBarrier {
    /// MPI_Test on the barrier request.
    pub fn is_done(&self) -> bool {
        self.sig.is_set()
    }

    /// Completion signal (for [`crate::mpi::WaitAny`]).
    pub fn signal(&self) -> &Signal {
        &self.sig
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    fn world(nodes: usize, ppn: usize) -> World {
        World::new(
            Topology::quartz(nodes, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
    }

    #[test]
    fn allreduce_sum_various_sizes() {
        for n in [1usize, 2, 3, 4, 5, 7, 8, 13, 16] {
            let out = world(1, n).run(|c| async move {
                let me = c.rank() as u64;
                c.allreduce(vec![me, 1, me * me], ReduceOp::Sum).await
            });
            let n64 = n as u64;
            let s: u64 = (0..n64).sum();
            let sq: u64 = (0..n64).map(|x| x * x).sum();
            for r in out.results {
                assert_eq!(r, vec![s, n64, sq], "n={n}");
            }
        }
    }

    #[test]
    fn allreduce_max() {
        let out = world(2, 3).run(|c| async move {
            let me = c.rank() as u64;
            c.allreduce(vec![me, 100 - me], ReduceOp::Max).await
        });
        for r in out.results {
            assert_eq!(r, vec![5, 100]);
        }
    }

    #[test]
    fn barrier_synchronizes() {
        let out = world(2, 4).run(|c| async move {
            // Rank 3 arrives late; everyone's exit time must be >= its entry.
            if c.rank() == 3 {
                c.sim().sleep(100_000).await;
            }
            c.barrier().await;
            c.now()
        });
        for t in out.results {
            assert!(t >= 100_000);
        }
    }

    #[test]
    fn ibarrier_not_done_until_all_enter() {
        let out = world(1, 4).run(|c| async move {
            if c.rank() == 0 {
                // Enter late; others must not see completion before this.
                c.sim().sleep(50_000).await;
            }
            let bar = c.ibarrier().await;
            let entered_at = c.now();
            let mut spins = 0u64;
            while !bar.is_done() {
                c.charge_cpu(200).await;
                spins += 1;
            }
            (entered_at, c.now(), spins)
        });
        for (_, done_at, _) in &out.results {
            assert!(*done_at >= 50_000, "ibarrier completed early: {done_at}");
        }
    }

    #[test]
    fn bcast_from_each_root() {
        for root in 0..5 {
            let out = world(1, 5).run(move |c| async move {
                let v = if c.rank() == root {
                    vec![7, 8, 9]
                } else {
                    Vec::new()
                };
                c.bcast(root, v).await
            });
            for r in out.results {
                assert_eq!(r, vec![7, 8, 9], "root={root}");
            }
        }
    }

    #[test]
    fn gather_collects_all() {
        let out = world(1, 4).run(|c| async move {
            let me = c.rank() as u64;
            c.gather(2, vec![me; (me + 1) as usize]).await
        });
        let g = out.results[2].as_ref().unwrap();
        for (i, v) in g.iter().enumerate() {
            assert_eq!(v, &vec![i as u64; i + 1]);
        }
        assert!(out.results[0].is_none());
    }

    #[test]
    fn alltoallv_exchanges() {
        let out = world(1, 4).run(|c| async move {
            let me = c.rank() as u64;
            let n = c.nranks();
            let bufs: Vec<Vec<u64>> = (0..n).map(|d| vec![me * 10 + d as u64]).collect();
            c.alltoallv(bufs).await
        });
        for (me, r) in out.results.iter().enumerate() {
            for (src, v) in r.iter().enumerate() {
                assert_eq!(v, &vec![src as u64 * 10 + me as u64]);
            }
        }
    }

    #[test]
    fn collectives_compose_in_sequence() {
        let out = world(1, 3).run(|c| async move {
            let a = c.allreduce(vec![1], ReduceOp::Sum).await;
            c.barrier().await;
            let b = c.allreduce(vec![2], ReduceOp::Sum).await;
            (a[0], b[0])
        });
        for (a, b) in out.results {
            assert_eq!((a, b), (3, 6));
        }
    }

    #[test]
    fn allreduce_cost_grows_with_ranks() {
        let time = |nodes: usize| {
            world(nodes, 8)
                .run(|c| async move {
                    c.allreduce(vec![0u64; 64], ReduceOp::Sum).await;
                })
                .end_time
        };
        let t2 = time(2);
        let t16 = time(16);
        assert!(t16 > t2, "allreduce at 16 nodes ({t16}) <= 2 nodes ({t2})");
    }
}
