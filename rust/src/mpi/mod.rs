//! Simulated MPI substrate over [`crate::simnet`].
//!
//! Everything the paper's SDDE algorithms touch is implemented here against
//! the virtual-time executor: two-sided p2p with unexpected-message queues,
//! eager + rendezvous protocols and synchronous-send semantics
//! ([`world`]), collectives built from p2p ([`coll`]), and one-sided RMA
//! windows ([`rma`]).
//!
//! One simulated MPI process == one async task holding a [`Comm`] handle.
//! Blocking MPI calls are `async fn`s; their cost is charged to the rank's
//! virtual CPU and NIC per the [`crate::simnet::CostModel`].

pub mod coll;
pub mod rma;
pub mod wait;
pub mod watchdog;
pub mod world;

pub use coll::{IBarrier, ReduceOp};
pub use rma::Window;
pub use wait::WaitAny;
pub use watchdog::{BlockedOp, MissReason, NearMiss, OpKind, RankWait, WaitGraph};
pub use world::{
    waitall, Comm, Counters, Msg, Payload, ProbeInfo, Request, RunOutput, World, WorldBuilder,
};

/// MPI-style message tag.
pub type Tag = u32;

/// Communicator context id — the invisible third component of the message
/// envelope. Matching keys on `(ctx, src, tag)`, so traffic on one
/// communicator can never satisfy a receive posted on another even when
/// `(src, tag)` collide. `CtxId::WORLD` (0) is reserved for the world
/// communicator: single-communicator runs never mint another context and
/// stay bit-identical with the pre-context stack (DESIGN.md invariant 10).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CtxId(pub u32);

impl CtxId {
    /// The world communicator's reserved context.
    pub const WORLD: CtxId = CtxId(0);
}

impl std::fmt::Display for CtxId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Wildcard source for receives/probes.
pub const ANY_SOURCE: usize = usize::MAX;
/// Wildcard tag for receives/probes.
pub const ANY_TAG: Tag = u32::MAX;

/// Tags at or above this value are reserved for library internals
/// (collectives, barriers, RMA control). User code must stay below.
pub const TAG_INTERNAL_BASE: Tag = 0xF000_0000;

pub(crate) const TAG_ALLREDUCE: Tag = TAG_INTERNAL_BASE;
pub(crate) const TAG_BARRIER: Tag = TAG_INTERNAL_BASE + 0x0100_0000;
pub(crate) const TAG_IBARRIER: Tag = TAG_INTERNAL_BASE + 0x0200_0000;
pub(crate) const TAG_BCAST: Tag = TAG_INTERNAL_BASE + 0x0300_0000;
pub(crate) const TAG_GATHER: Tag = TAG_INTERNAL_BASE + 0x0400_0000;
pub(crate) const TAG_ALLTOALL: Tag = TAG_INTERNAL_BASE + 0x0500_0000;
/// Pseudo-family: per-communicator RMA window sequence numbers. Never put
/// on the wire — used only as a `next_seq` key so collective window
/// allocation order identifies windows across ranks (see [`rma`]).
pub(crate) const TAG_WIN: Tag = TAG_INTERNAL_BASE + 0x0600_0000;
