//! One-sided RMA: window allocation, `MPI_Put`, and fence synchronization.
//!
//! This is the substrate for the paper's Algorithm 3 (the CELLAR-style
//! constant-size SDDE): puts deposit words directly into the target
//! window with *no matching cost* at the target; a fence completes once all
//! locally-issued puts have been delivered everywhere (wait-own-puts, then
//! dissemination barrier, plus a fixed window-synchronization overhead).

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use super::world::Comm;
use super::TAG_WIN;
use crate::simnet::Tier;
use crate::trace::{Event, EventKind};

/// Target-side storage for one window at one rank.
pub(crate) struct WinState {
    pub data: Vec<u64>,
}

/// Handle to a window allocated by [`Comm::win_allocate`]. Windows are
/// identified by (context, per-communicator allocation seq) — collective
/// allocation order *on the owning communicator* — so windows line up
/// across ranks even when several communicators allocate concurrently.
/// Each rank holds `words` u64 slots.
pub struct Window {
    comm: Comm,
    id: (u32, u32),
    words: usize,
    /// Puts issued by this rank not yet delivered (epoch-local).
    outstanding: Rc<Cell<u64>>,
    /// Latest scheduled arrival among this rank's puts (fence waits here).
    last_arrival: Rc<Cell<crate::simnet::Time>>,
}

impl Comm {
    /// Collectively allocate a window with `words` u64 slots per rank,
    /// zero-initialized. All ranks must call it in the same order (per
    /// communicator — other communicators' allocations don't interfere).
    pub async fn win_allocate(&self, words: usize) -> Window {
        let id = (self.ctx().0, self.next_seq(TAG_WIN));
        {
            let mut r = self.state.ranks[self.world_rank()].borrow_mut();
            let prev = r.windows.insert(
                id,
                WinState {
                    data: vec![0; words],
                },
            );
            debug_assert!(prev.is_none(), "window id allocated twice");
        }
        // Window creation synchronizes (and pays the fence overhead once).
        self.barrier().await;
        self.charge_cpu(self.cost().rma_fence_overhead).await;
        Window {
            comm: self.clone(),
            id,
            words,
            outstanding: Rc::new(Cell::new(0)),
            last_arrival: Rc::new(Cell::new(0)),
        }
    }
}

impl Window {
    pub fn words(&self) -> usize {
        self.words
    }

    /// `MPI_Put`: deposit `vals` into `dst`'s window at `offset` words
    /// (`dst` is comm-local). Origin-side cost only; completion is
    /// deferred to the next fence. `wire_bytes` models the datatype (4
    /// for MPI_INT payloads).
    pub async fn put(&self, dst: usize, offset: usize, vals: &[u64], wire_bytes_per: usize) {
        let c = &self.comm;
        assert!(offset + vals.len() <= self.words, "put out of window bounds");
        let bytes = vals.len() * wire_bytes_per;
        let me = c.world_rank();
        let dst = c.to_world(dst);
        let tier = c.topo().tier(me, dst);

        c.bump_counter(|ct| {
            ct.rma_puts += 1;
            let t = tier as usize;
            ct.user_msgs[t] += 1;
            ct.user_bytes[t] += bytes as u64;
            if tier == Tier::InterNode {
                ct.internode_sent[me] += 1;
            }
        });

        // Origin software overhead.
        c.charge_cpu(c.cost().rma_put_overhead).await;

        // NIC serialization + wire through the shared fabric path (same
        // contention as p2p), but no matching at the target.
        let t0 = c.now();
        let (_inject_end, arrival) = c.state.transfer_times(me, dst, tier, bytes, bytes);
        if c.state.tracer.enabled() {
            c.state.tracer.record(Event {
                kind: EventKind::RmaPut,
                ctx: c.ctx(),
                rank: me,
                peer: dst,
                tag: 0,
                bytes,
                tier,
                t_start: t0,
                t_end: arrival,
                msg_id: 0,
            });
        }
        self.last_arrival
            .set(self.last_arrival.get().max(arrival));
        let (state, id) = (c.state.clone(), self.id);
        self.outstanding.set(self.outstanding.get() + 1);
        let outstanding = self.outstanding.clone();
        let vals = vals.to_vec();
        c.sim().schedule(arrival, move || {
            state.sim.note_progress();
            let mut r = state.ranks[dst].borrow_mut();
            let win = r.windows.get_mut(&id).expect("put into unallocated window");
            win.data[offset..offset + vals.len()].copy_from_slice(&vals);
            drop(r);
            outstanding.set(outstanding.get() - 1);
        });
    }

    /// Fence: completes the access epoch. After it returns, every put
    /// issued by *any* rank before its fence is visible in the windows.
    pub async fn fence(&self) {
        let c = &self.comm;
        // Wait for this rank's own puts to land (delivery times are known
        // when the puts are issued, so sleep straight to the last one —
        // the arrival events sort before this wake at equal timestamps)...
        if self.outstanding.get() > 0 {
            c.sim().sleep_until(self.last_arrival.get()).await;
            debug_assert_eq!(self.outstanding.get(), 0, "puts outlived their arrival time");
        }
        // ...then synchronize with everyone else.
        c.barrier().await;
        c.charge_cpu(c.cost().rma_fence_overhead).await;
    }

    /// Read `len` words of the local window at `offset`.
    pub fn read_local(&self, offset: usize, len: usize) -> Vec<u64> {
        let r = self.comm.state.ranks[self.comm.world_rank()].borrow();
        r.windows[&self.id].data[offset..offset + len].to_vec()
    }

    /// Overwrite the local window contents (e.g. reset between epochs).
    pub fn fill_local(&self, value: u64) {
        let mut r = self.comm.state.ranks[self.comm.world_rank()].borrow_mut();
        for w in r
            .windows
            .get_mut(&self.id)
            .expect("window not allocated on this rank")
            .data
            .iter_mut()
        {
            *w = value;
        }
    }
}

// RefCell/Rc types above are single-thread only — matches the executor.
#[allow(unused)]
fn _assert_sizes(_: &RefCell<WinState>) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    fn world(nodes: usize, ppn: usize) -> World {
        World::new(
            Topology::quartz(nodes, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
    }

    #[test]
    fn put_visible_after_fence() {
        let out = world(2, 2).run(|c| async move {
            let n = c.nranks();
            let me = c.rank();
            let win = c.win_allocate(n).await;
            win.fence().await;
            // Everyone puts its rank+1 into slot `me` of every other rank.
            for dst in 0..n {
                if dst != me {
                    win.put(dst, me, &[(me + 1) as u64], 4).await;
                }
            }
            win.fence().await;
            win.read_local(0, n)
        });
        for (me, r) in out.results.iter().enumerate() {
            for (slot, &v) in r.iter().enumerate() {
                if slot == me {
                    assert_eq!(v, 0);
                } else {
                    assert_eq!(v, (slot + 1) as u64, "rank {me} slot {slot}");
                }
            }
        }
    }

    #[test]
    fn fence_waits_for_slow_put() {
        // Rank 0 puts a large value late; rank 1 must still see it after the
        // fence (the barrier inside fence orders the epochs).
        let out = world(2, 1).run(|c| async move {
            let win = c.win_allocate(4).await;
            win.fence().await;
            if c.rank() == 0 {
                c.sim().sleep(30_000).await;
                win.put(1, 0, &[99, 98, 97, 96], 4).await;
            }
            win.fence().await;
            win.read_local(0, 4)
        });
        assert_eq!(out.results[1], vec![99, 98, 97, 96]);
    }

    #[test]
    #[should_panic(expected = "out of window bounds")]
    fn put_bounds_checked() {
        world(1, 2)
            .run(|c| async move {
                let win = c.win_allocate(2).await;
                if c.rank() == 0 {
                    win.put(1, 1, &[1, 2], 4).await;
                }
                win.fence().await;
            })
            .end_time;
    }

    #[test]
    fn rma_counters() {
        let out = world(2, 1).run(|c| async move {
            let win = c.win_allocate(2).await;
            win.fence().await;
            if c.rank() == 0 {
                win.put(1, 0, &[5], 4).await;
            }
            win.fence().await;
        });
        assert_eq!(out.counters.rma_puts, 1);
        assert_eq!(out.counters.internode_sent[0], 1);
    }

    #[test]
    fn multiple_windows_independent() {
        let out = world(1, 2).run(|c| async move {
            let w1 = c.win_allocate(1).await;
            let w2 = c.win_allocate(1).await;
            w1.fence().await;
            w2.fence().await;
            if c.rank() == 0 {
                w1.put(1, 0, &[11], 8).await;
                w2.put(1, 0, &[22], 8).await;
            }
            w1.fence().await;
            w2.fence().await;
            (w1.read_local(0, 1)[0], w2.read_local(0, 1)[0])
        });
        assert_eq!(out.results[1], (11, 22));
    }
}
