//! Event-driven waiting for NBX-style progress loops.
//!
//! The NBX control flow is "test sends / test barrier / probe" in a spin
//! loop. Simulating every `MPI_Test` poll literally would create millions
//! of no-op events at scale, so [`WaitAny`] sleeps the rank until one of
//! its wake conditions can have changed: a message arrival or a [`Signal`]
//! (all-sends-complete, barrier-complete). The virtual-time cost of the
//! *useful* operations (the probe/match on wake) is still charged by the
//! caller; only the fruitless polls are elided — they would not have
//! delayed completion in a real MPI either, since the rank was
//! idle-waiting.

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

use super::world::{Comm, Request};
use crate::simnet::{Tier, Time};
use crate::trace::{Event, EventKind};

/// One-shot boolean condition with waker registration (O(1) per wake —
/// no rescanning of request arrays).
#[derive(Clone, Default)]
pub struct Signal(Rc<RefCell<SignalState>>);

#[derive(Default)]
struct SignalState {
    set: bool,
    wakers: Vec<Waker>,
}

impl Signal {
    pub fn new() -> Signal {
        Signal::default()
    }

    pub fn set(&self) {
        let mut st = self.0.borrow_mut();
        st.set = true;
        for w in st.wakers.drain(..) {
            w.wake();
        }
    }

    pub fn is_set(&self) -> bool {
        self.0.borrow().set
    }

    /// Register a waker to fire on [`Signal::set`] (no-op if already set).
    /// NBX progress loops re-poll the same [`WaitAny`] many times between
    /// wakes; duplicate registrations from one task are deduplicated so
    /// the waker list stays O(waiting tasks), not O(polls).
    pub fn register(&self, waker: &Waker) {
        let mut st = self.0.borrow_mut();
        if !st.set && !st.wakers.iter().any(|w| w.will_wake(waker)) {
            st.wakers.push(waker.clone());
        }
    }
}

/// Signal that fires once every request in `reqs` has completed.
/// Registration is O(len) once; each completion is O(1).
pub fn all_done_signal(reqs: &[Request]) -> Signal {
    let sig = Signal::new();
    let pending = Rc::new(std::cell::Cell::new(0usize));
    for r in reqs {
        if !r.is_done() {
            pending.set(pending.get() + 1);
            let pending = pending.clone();
            let sig2 = sig.clone();
            r.on_complete(move || {
                pending.set(pending.get() - 1);
                if pending.get() == 0 {
                    sig2.set();
                }
            });
        }
    }
    if pending.get() == 0 {
        sig.set();
    }
    sig
}

/// Completes when a message has arrived at the rank since `epoch0`, or any
/// of the given signals is set.
pub struct WaitAny<'a> {
    comm: &'a Comm,
    epoch0: u64,
    signals: &'a [&'a Signal],
    /// Virtual time at construction — start of the traced wait span.
    t0: Time,
}

impl<'a> WaitAny<'a> {
    pub fn new(comm: &'a Comm, signals: &'a [&'a Signal]) -> WaitAny<'a> {
        WaitAny {
            comm,
            epoch0: comm.arrival_epoch(),
            signals,
            t0: comm.now(),
        }
    }

    /// Sample the arrival epoch *before* a probe so an arrival landing
    /// between the probe and the wait still wakes immediately.
    pub fn with_epoch(mut self, epoch0: u64) -> Self {
        self.epoch0 = epoch0;
        self
    }
}

impl WaitAny<'_> {
    /// Trace the resolved wait span `[t0, now]` (no-op when disabled or
    /// when the wait resolved without advancing virtual time).
    fn trace_wait(&self) {
        let st = &self.comm.state;
        let now = st.sim.now();
        if now > self.t0 && st.tracer.enabled() {
            st.tracer.record(Event {
                kind: EventKind::Wait,
                ctx: self.comm.ctx(),
                rank: self.comm.world_rank(),
                peer: self.comm.world_rank(),
                tag: 0,
                bytes: 0,
                tier: Tier::SelfMsg,
                t_start: self.t0,
                t_end: now,
                msg_id: 0,
            });
        }
    }
}

impl Future for WaitAny<'_> {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.comm.arrival_epoch() != self.epoch0 {
            self.trace_wait();
            return Poll::Ready(());
        }
        if self.signals.iter().any(|s| s.is_set()) {
            self.trace_wait();
            return Poll::Ready(());
        }
        self.comm.register_arrival_waker(cx.waker());
        for s in self.signals {
            s.register(cx.waker());
        }
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use crate::mpi::{Payload, World};
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    use super::*;

    fn world(ppn: usize) -> World {
        World::new(
            Topology::quartz(1, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
    }

    #[test]
    fn wakes_on_arrival() {
        let out = world(2).run(|c| async move {
            if c.rank() == 0 {
                c.sim().sleep(10_000).await;
                c.send(1, 1, Payload::ints(&[1])).await;
                0
            } else {
                WaitAny::new(&c, &[]).await;
                let t = c.now();
                c.recv(0, 1).await;
                t
            }
        });
        assert!(out.results[1] >= 10_000);
    }

    #[test]
    fn wakes_on_request_completion() {
        let out = world(2).run(|c| async move {
            if c.rank() == 0 {
                let req = c.issend(1, 1, Payload::ints(&[1])).await;
                let sig = all_done_signal(&[req]);
                while !sig.is_set() {
                    WaitAny::new(&c, &[&sig]).await;
                }
                c.now()
            } else {
                c.sim().sleep(20_000).await;
                c.recv(0, 1).await;
                0
            }
        });
        assert!(out.results[0] >= 20_000);
    }

    #[test]
    fn wakes_on_barrier_done() {
        let out = world(3).run(|c| async move {
            if c.rank() == 2 {
                c.sim().sleep(30_000).await;
            }
            let bar = c.ibarrier().await;
            while !bar.is_done() {
                WaitAny::new(&c, &[bar.signal()]).await;
            }
            c.now()
        });
        for t in out.results {
            assert!(t >= 30_000);
        }
    }

    #[test]
    fn all_done_signal_empty_and_completed() {
        let sig = all_done_signal(&[]);
        assert!(sig.is_set());
        let out = world(1).run(|c| async move {
            // a self-send completes immediately after injection
            let r = c.isend(0, 1, Payload::ints(&[1])).await;
            r.clone().await;
            let sig = all_done_signal(&[r]);
            let ok = sig.is_set();
            c.recv(0, 1).await;
            ok
        });
        assert!(out.results[0]);
    }

    #[test]
    fn immediate_if_signal_already_set() {
        let out = world(1).run(|c| async move {
            let sig = Signal::new();
            sig.set();
            WaitAny::new(&c, &[&sig]).await;
            c.now()
        });
        assert_eq!(out.results[0], 0);
    }
}
