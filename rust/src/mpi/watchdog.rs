//! Hang diagnosis: turn a stalled simulation into a `WaitGraph` report.
//!
//! When `Sim::try_run` stalls (true deadlock: empty timer heap with live
//! tasks; or quiescence: the virtual-time watchdog tripped), `World`
//! assembles a [`WaitGraph`] from per-rank state instead of hanging or
//! panicking bare: every blocked operation with the envelope it waits
//! for, the *nearest-miss* unexpected messages sitting in that rank's
//! queue (same source but wrong tag, same tag but wrong source — the
//! classic mismatched-tag bug — or a matching `(src, tag)` on a
//! *different communicator context*, the classic cross-communicator
//! bug), and a wait-for cycle if one exists (send/send deadlocks).
//!
//! Blocked receives are read straight off the posted-receive queues.
//! Operations with no queue footprint — synchronous/rendezvous sends
//! waiting for a match, blocking probes — are tracked in a host-side
//! per-rank registry: registered when the wait begins, removed by an
//! `on_complete` callback or an RAII [`OpGuard`]. The registry never
//! touches virtual time, so diagnosis stays observational (invariant 8's
//! bit-identity is unaffected by it).

use std::rc::{Rc, Weak};

use super::world::WorldState;
use super::{CtxId, Tag, ANY_SOURCE, ANY_TAG};
use crate::simnet::{Stall, Time};

/// What a blocked operation is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// A posted receive that never matched.
    Recv,
    /// A synchronous send (issend) waiting for the receiver to match.
    SyncSend,
    /// A rendezvous send waiting for the receiver to match and pull.
    RendezvousSend,
    /// A blocking probe waiting for a matching envelope.
    Probe,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Recv => "recv",
            OpKind::SyncSend => "sync-send",
            OpKind::RendezvousSend => "rendezvous-send",
            OpKind::Probe => "probe",
        }
    }
}

/// One blocked operation: the envelope it is waiting on.
#[derive(Clone, Debug)]
pub struct BlockedOp {
    pub kind: OpKind,
    /// Communicator context the operation was issued on.
    pub ctx: CtxId,
    /// Peer *world* rank (source for recv/probe, destination for sends);
    /// may be [`ANY_SOURCE`] for wildcard receives/probes.
    pub peer: usize,
    /// Tag; may be [`ANY_TAG`].
    pub tag: Tag,
    /// Virtual time the wait began (`None` for posted receives, which
    /// have no registry entry).
    pub since: Option<Time>,
}

/// Why an unexpected message *almost* matched a blocked receive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MissReason {
    /// Source matches the spec, tag does not (mismatched-tag bug).
    TagMismatch,
    /// Tag matches the spec, source does not.
    SrcMismatch,
    /// `(src, tag)` match the spec but the message was sent on a
    /// different communicator (cross-communicator bug).
    CtxMismatch,
}

/// An unexpected message that nearly matches one of a rank's blocked
/// receives — the most actionable hint in a mismatched-envelope hang.
#[derive(Clone, Debug)]
pub struct NearMiss {
    /// Envelope of the unexpected message.
    pub ctx: CtxId,
    pub src: usize,
    pub tag: Tag,
    /// The blocked spec it nearly matched.
    pub wanted_ctx: CtxId,
    pub wanted_peer: usize,
    pub wanted_tag: Tag,
    pub reason: MissReason,
}

/// Everything known about one blocked rank.
#[derive(Clone, Debug)]
pub struct RankWait {
    pub rank: usize,
    pub ops: Vec<BlockedOp>,
    pub near_misses: Vec<NearMiss>,
    /// Depth of the rank's unexpected queue at stall time.
    pub unexpected: usize,
}

/// The full stall diagnostic returned by `World::run_checked`.
#[derive(Clone, Debug)]
pub struct WaitGraph {
    pub stall: Stall,
    /// Virtual time at which the stall was declared.
    pub at: Time,
    /// Blocked ranks (ranks with no pending ops are omitted).
    pub blocked: Vec<RankWait>,
    /// A wait-for cycle among blocked ranks, if one exists (closed path:
    /// first and last element are the same rank).
    pub cycle: Option<Vec<usize>>,
}

fn fmt_peer(p: usize) -> String {
    if p == ANY_SOURCE {
        "any".into()
    } else {
        p.to_string()
    }
}

fn fmt_tag(t: Tag) -> String {
    if t == ANY_TAG {
        "any".into()
    } else {
        format!("{t:#x}")
    }
}

impl WaitGraph {
    /// Ranks that appear blocked.
    pub fn blocked_ranks(&self) -> Vec<usize> {
        self.blocked.iter().map(|b| b.rank).collect()
    }

    /// All blocked ops of `rank` (empty if the rank isn't blocked).
    pub fn ops_of(&self, rank: usize) -> Vec<BlockedOp> {
        self.blocked
            .iter()
            .find(|b| b.rank == rank)
            .map(|b| b.ops.clone())
            .unwrap_or_default()
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let kind = match self.stall {
            Stall::Deadlock { .. } => "deadlock",
            Stall::Quiescent { .. } => "quiescent (watchdog)",
        };
        out.push_str(&format!(
            "wait graph: {} at t={} — {} blocked rank(s), {} live task(s)\n",
            kind,
            self.at,
            self.blocked.len(),
            self.stall.live_tasks()
        ));
        if let Stall::Quiescent { last_progress, .. } = self.stall {
            out.push_str(&format!("  last progress at t={last_progress}\n"));
        }
        for b in &self.blocked {
            for op in &b.ops {
                let dir = match op.kind {
                    OpKind::Recv | OpKind::Probe => "from",
                    OpKind::SyncSend | OpKind::RendezvousSend => "to",
                };
                let since = op
                    .since
                    .map(|t| format!(" since t={t}"))
                    .unwrap_or_default();
                // Name the communicator only off the world context, so
                // single-communicator reports render exactly as before.
                let on_ctx = if op.ctx == CtxId::WORLD {
                    String::new()
                } else {
                    format!(" on ctx {}", op.ctx)
                };
                out.push_str(&format!(
                    "  rank {}: blocked {} {} {} tag {}{}{}\n",
                    b.rank,
                    op.kind.name(),
                    dir,
                    fmt_peer(op.peer),
                    fmt_tag(op.tag),
                    on_ctx,
                    since
                ));
            }
            for nm in &b.near_misses {
                let why = match nm.reason {
                    MissReason::TagMismatch => "tag mismatch".to_string(),
                    MissReason::SrcMismatch => "source mismatch".to_string(),
                    MissReason::CtxMismatch => format!(
                        "context mismatch (msg on ctx {}, recv on ctx {})",
                        nm.ctx, nm.wanted_ctx
                    ),
                };
                out.push_str(&format!(
                    "    near miss: unexpected msg from {} tag {} \
                     vs wanted ({}, {}) — {}\n",
                    nm.src,
                    fmt_tag(nm.tag),
                    fmt_peer(nm.wanted_peer),
                    fmt_tag(nm.wanted_tag),
                    why
                ));
            }
            if b.unexpected > 0 {
                out.push_str(&format!(
                    "    unexpected queue depth: {}\n",
                    b.unexpected
                ));
            }
        }
        match &self.cycle {
            Some(path) => {
                let s: Vec<String> = path.iter().map(|r| r.to_string()).collect();
                out.push_str(&format!("  cycle: {}\n", s.join(" -> ")));
            }
            None => out.push_str("  no wait cycle (missing counterpart)\n"),
        }
        out
    }
}

/// RAII registration of a blocked op (used by blocking probes): the entry
/// is removed when the guard drops, however the wait ends. Holds only a
/// weak reference, so a guard leaked across a dropped world is inert.
pub(crate) struct OpGuard {
    state: Weak<WorldState>,
    rank: usize,
    id: u64,
}

impl OpGuard {
    pub(crate) fn register(state: &Rc<WorldState>, rank: usize, op: BlockedOp) -> OpGuard {
        let id = state.register_op(rank, op);
        OpGuard {
            state: Rc::downgrade(state),
            rank,
            id,
        }
    }
}

impl Drop for OpGuard {
    fn drop(&mut self) {
        if let Some(s) = self.state.upgrade() {
            s.unregister_op(self.rank, self.id);
        }
    }
}

/// Assemble the diagnostic from a stalled world's rank state.
pub(crate) fn collect_wait_graph(state: &WorldState, stall: Stall) -> WaitGraph {
    let at = state.sim.now();
    let mut blocked = Vec::new();
    for (rank, cell) in state.ranks.iter().enumerate() {
        let r = cell.borrow();
        let mut ops: Vec<BlockedOp> = r
            .watchdog_recvs()
            .into_iter()
            .map(|(ctx, src, tag)| BlockedOp {
                kind: OpKind::Recv,
                ctx,
                peer: src,
                tag,
                since: None,
            })
            .collect();
        ops.extend(r.watchdog_ops());
        if ops.is_empty() {
            continue;
        }
        let unexpected_env = r.watchdog_unexpected();
        let mut near_misses = Vec::new();
        for op in ops.iter().filter(|o| matches!(o.kind, OpKind::Recv | OpKind::Probe)) {
            for &(ctx, src, tag) in &unexpected_env {
                let ctx_ok = op.ctx == ctx;
                let src_ok = op.peer == ANY_SOURCE || op.peer == src;
                let tag_ok = op.tag == ANY_TAG || op.tag == tag;
                let reason = match (ctx_ok, src_ok, tag_ok) {
                    (true, true, false) => MissReason::TagMismatch,
                    (true, false, true) => MissReason::SrcMismatch,
                    (false, true, true) => MissReason::CtxMismatch,
                    // Full match (blocked elsewhere) or a ≥2-component
                    // mismatch: neither is a *near* miss.
                    _ => continue,
                };
                near_misses.push(NearMiss {
                    ctx,
                    src,
                    tag,
                    wanted_ctx: op.ctx,
                    wanted_peer: op.peer,
                    wanted_tag: op.tag,
                    reason,
                });
            }
        }
        near_misses.truncate(8); // keep reports readable on deep queues
        blocked.push(RankWait {
            rank,
            ops,
            near_misses,
            unexpected: unexpected_env.len(),
        });
    }
    let cycle = find_cycle(&blocked);
    WaitGraph {
        stall,
        at,
        blocked,
        cycle,
    }
}

/// Wait-for cycle detection over the concrete-peer edges of blocked ranks
/// (wildcard specs contribute no edge). DFS with tricolor marking;
/// returns a closed path `[a, …, a]` if a cycle exists.
fn find_cycle(blocked: &[RankWait]) -> Option<Vec<usize>> {
    use std::collections::BTreeMap;
    let mut edges: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for b in blocked {
        let peers: Vec<usize> = b
            .ops
            .iter()
            .filter(|o| o.peer != ANY_SOURCE)
            .map(|o| o.peer)
            .collect();
        edges.insert(b.rank, peers);
    }

    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    let mut color: BTreeMap<usize, Color> =
        edges.keys().map(|&k| (k, Color::White)).collect();

    fn dfs(
        v: usize,
        edges: &BTreeMap<usize, Vec<usize>>,
        color: &mut BTreeMap<usize, Color>,
        path: &mut Vec<usize>,
    ) -> Option<Vec<usize>> {
        color.insert(v, Color::Gray);
        path.push(v);
        if let Some(peers) = edges.get(&v) {
            for &p in peers {
                match color.get(&p) {
                    Some(Color::Gray) => {
                        // Found a back edge: close the cycle from p.
                        let start = path.iter().position(|&x| x == p).unwrap();
                        let mut cyc = path[start..].to_vec();
                        cyc.push(p);
                        return Some(cyc);
                    }
                    Some(Color::White) => {
                        if let Some(c) = dfs(p, edges, color, path) {
                            return Some(c);
                        }
                    }
                    // Black (explored) or not a blocked rank: no cycle here.
                    _ => {}
                }
            }
        }
        path.pop();
        color.insert(v, Color::Black);
        None
    }

    let starts: Vec<usize> = edges.keys().copied().collect();
    for s in starts {
        if color[&s] == Color::White {
            let mut path = Vec::new();
            if let Some(c) = dfs(s, &edges, &mut color, &mut path) {
                return Some(c);
            }
        }
    }
    None
}
