//! Evidence-driven SDDE algorithm selection (the paper's §VI future-work
//! hook, grown into a subsystem).
//!
//! [`select`] maps measured pattern statistics ([`PatternStats`]) to a
//! [`Selection`] — the chosen [`SddeAlgorithm`] plus a human-readable
//! rationale and, when a calibrated [`DispatchModel`] is loaded, the full
//! per-algorithm score breakdown. Three sources, in priority order:
//!
//! 1. **Explicit** — `MpixInfo::algorithm != Dispatch`: no decision to make
//!    (validation of RMA-on-variable still applies, in `mpix::select_algorithm`).
//! 2. **Model** — a [`DispatchModel`] calibrated by `sdde calibrate` from
//!    figure sweeps (fault-free makespan), chaos sweeps (per-fault-profile
//!    makespan inflation) and traced critical paths (wait share by event
//!    kind). Scores are robustness-weighted:
//!    `score = base × (1 + w·(inflation − 1))`, so an algorithm that wins
//!    fault-free but collapses under jitter loses the pick on a noisy
//!    machine. A default model ships embedded in the binary
//!    ([`DispatchModel::embedded`]); `--dispatch-model PATH` overrides it.
//! 3. **Heuristic** — no model loaded: the legacy three-branch thresholds,
//!    reproduced bit-for-bit (invariant 9 in DESIGN.md; enforced by the
//!    grid-equivalence test in `tests/dispatch.rs`).
//!
//! The model file is handwritten JSON (parsed with [`crate::util::json`];
//! the build is offline, no serde). Buckets discretize the stats space
//! along the same axes the legacy heuristic used — scale (`small` < 64
//! ranks ≤ `mid` < 256 ≤ `large`), density (`dense` iff
//! `send_nnz > 2·region_size`), and API variant (`crs`/`crsv`) — so the
//! calibrated table refines the threshold space instead of reinventing it.

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{anyhow, Context, Result};

use super::{MpixComm, SddeAlgorithm};
use crate::util::{fmt, json};

/// Measured statistics of one rank's SDDE call — the model's feature
/// vector. Cheap to compute from the send side alone (the receive side is,
/// by definition of the problem, unknown).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PatternStats {
    /// World size.
    pub nranks: usize,
    /// Ranks in this rank's aggregation region (PPN for node regions).
    pub region_size: usize,
    /// Number of destination ranks (`dest.len()`; the paper's send nnz).
    pub send_nnz: usize,
    /// Fraction of destinations inside this rank's own region — how much
    /// traffic locality-aware aggregation can keep off the network.
    pub local_frac: f64,
    /// `true` for `MPIX_Alltoall_crs`, `false` for `MPIX_Alltoallv_crs`
    /// (the RMA algorithms only exist for the former — paper §IV-C).
    pub constant: bool,
}

impl PatternStats {
    /// Measure the stats of an SDDE call about to run on `mx`.
    pub fn measure(mx: &MpixComm, dest: &[usize], constant: bool) -> PatternStats {
        let me = mx.my_region();
        let local = dest.iter().filter(|&&d| mx.region(d) == me).count();
        PatternStats {
            nranks: mx.comm.nranks(),
            region_size: mx.region_size_of(mx.comm.rank()),
            send_nnz: dest.len(),
            local_frac: if dest.is_empty() {
                0.0
            } else {
                local as f64 / dest.len() as f64
            },
            constant,
        }
    }

    /// The model bucket these stats fall into.
    pub fn bucket(&self) -> String {
        bucket_key(self)
    }
}

/// Discretize stats into a model bucket: `scale/density/variant`.
pub fn bucket_key(stats: &PatternStats) -> String {
    let scale = if stats.nranks >= 256 {
        "large"
    } else if stats.nranks >= 64 {
        "mid"
    } else {
        "small"
    };
    let density = if stats.send_nnz > 2 * stats.region_size {
        "dense"
    } else {
        "sparse"
    };
    let variant = if stats.constant { "crs" } else { "crsv" };
    format!("{scale}/{density}/{variant}")
}

/// Where a [`Selection`] came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionSource {
    /// The caller named a concrete algorithm; no decision was made.
    Explicit,
    /// Legacy threshold heuristic (no model loaded, or bucket uncovered).
    Heuristic,
    /// Robustness-weighted score from a calibrated [`DispatchModel`].
    Model,
}

/// One algorithm's scored row in a selection.
#[derive(Clone, Debug, PartialEq)]
pub struct AlgoScore {
    pub algo: SddeAlgorithm,
    /// Fault-free makespan relative to the bucket's best (1.0 = fastest).
    pub base: f64,
    /// Makespan inflation under the requested noise regime (1.0 = none).
    pub inflation: f64,
    /// Critical-path wait share (fraction of the covered makespan spent in
    /// `Wait` events) — a tiebreaker: equal scores prefer less waiting.
    pub cp_wait: f64,
    /// `base × (1 + w·(inflation − 1))` — lower is better.
    pub score: f64,
}

/// The outcome of a dispatch decision.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub algo: SddeAlgorithm,
    /// Human-readable justification (printed by `sdde dispatch` and the
    /// sweep tables).
    pub rationale: String,
    /// Full scored ranking, best first (empty for explicit/heuristic
    /// selections — they don't score).
    pub scores: Vec<AlgoScore>,
    pub source: SelectionSource,
}

impl Selection {
    /// A selection that was never in question.
    pub fn explicit(algo: SddeAlgorithm) -> Selection {
        Selection {
            algo,
            rationale: "explicitly requested via MpixInfo::algorithm".to_string(),
            scores: Vec::new(),
            source: SelectionSource::Explicit,
        }
    }
}

/// One calibrated table row: an algorithm's evidence within one bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    /// Bucket key (see [`bucket_key`]).
    pub bucket: String,
    pub algo: SddeAlgorithm,
    /// Mean fault-free makespan relative to the bucket's per-cell best.
    pub base: f64,
    /// Critical-path wait share measured from a traced run.
    pub cp_wait: f64,
    /// Mean makespan inflation per fault profile, `(profile name, ratio)`.
    pub inflation: Vec<(String, f64)>,
}

/// A calibrated selection model: the score table `sdde calibrate` emits
/// and [`select`] consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchModel {
    /// Robustness weight `w` in `score = base × (1 + w·(inflation − 1))`.
    /// 0 ranks purely fault-free; 1 weighs inflation at face value.
    pub robustness: f64,
    /// Fault-profile names the entries were calibrated against, in
    /// presentation order.
    pub profiles: Vec<String>,
    pub entries: Vec<ModelEntry>,
}

/// Deterministic tie-break order for algorithms (table order of the
/// paper's listing; also the order score tables print in).
fn algo_rank(a: SddeAlgorithm) -> usize {
    SddeAlgorithm::CONST_SIZE
        .iter()
        .position(|&x| x == a)
        .unwrap_or(SddeAlgorithm::CONST_SIZE.len())
}

impl DispatchModel {
    /// The calibrated model shipped in the binary. Regenerate with
    /// `sdde calibrate --out rust/src/mpix/dispatch_default.json`.
    pub fn embedded() -> &'static DispatchModel {
        static EMBEDDED: OnceLock<DispatchModel> = OnceLock::new();
        EMBEDDED.get_or_init(|| {
            DispatchModel::from_json(include_str!("dispatch_default.json"))
                .expect("embedded dispatch model must parse")
        })
    }

    /// Parse a model from its JSON serialization.
    pub fn from_json(text: &str) -> Result<DispatchModel> {
        let doc = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        if let Some(v) = doc.get("version").and_then(|v| v.as_f64()) {
            if v != 1.0 {
                anyhow::bail!("unsupported dispatch-model version {v}");
            }
        }
        let robustness = doc
            .get("robustness")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0);
        let profiles: Vec<String> = doc
            .get("profiles")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(|s| s.to_string()))
            .collect();
        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(|v| v.as_arr())
            .context("dispatch model has no 'entries' array")?
        {
            let bucket = e
                .get("bucket")
                .and_then(|v| v.as_str())
                .context("entry missing 'bucket'")?
                .to_string();
            let algo_name = e
                .get("algo")
                .and_then(|v| v.as_str())
                .context("entry missing 'algo'")?;
            let algo = SddeAlgorithm::parse(algo_name).map_err(|e| anyhow!("{e}"))?;
            let base = e
                .get("base")
                .and_then(|v| v.as_f64())
                .context("entry missing 'base'")?;
            let cp_wait = e.get("cp_wait").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let mut inflation = Vec::new();
            if let Some(fields) = e.get("inflation").and_then(|v| v.as_obj()) {
                for (name, v) in fields {
                    inflation.push((
                        name.clone(),
                        v.as_f64()
                            .with_context(|| format!("inflation '{name}' not a number"))?,
                    ));
                }
            }
            entries.push(ModelEntry {
                bucket,
                algo,
                base,
                cp_wait,
                inflation,
            });
        }
        Ok(DispatchModel {
            robustness,
            profiles,
            entries,
        })
    }

    /// Serialize (stable field order; reparsing yields an equal model).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"robustness\": {},\n", self.robustness));
        out.push_str("  \"profiles\": [");
        for (i, p) in self.profiles.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\"", json::escape(p)));
        }
        out.push_str("],\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"bucket\": \"{}\", \"algo\": \"{}\", \"base\": {}, \"cp_wait\": {}, \"inflation\": {{",
                json::escape(&e.bucket),
                e.algo.name(),
                e.base,
                e.cp_wait
            ));
            for (j, (name, v)) in e.inflation.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("\"{}\": {}", json::escape(name), v));
            }
            out.push_str("}}");
            out.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Load a model from a JSON file.
    pub fn load(path: &Path) -> Result<DispatchModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parse {}", path.display()))
    }

    /// Write the model as JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("write {}", path.display()))
    }

    /// Inflation ratio of one entry under a noise regime (`None`/"none" =
    /// fault-free = 1.0; a profile the entry was not calibrated against
    /// also scores 1.0).
    fn inflation_of(entry: &ModelEntry, noise: Option<&str>) -> f64 {
        match noise {
            None | Some("none") | Some("off") => 1.0,
            Some(n) => entry
                .inflation
                .iter()
                .find(|(p, _)| p == n)
                .map(|(_, v)| *v)
                .unwrap_or(1.0),
        }
    }

    /// Scored ranking for one bucket (best first; deterministic order).
    /// `constant = false` filters the RMA algorithms even if the table
    /// carries them.
    fn scores_for_bucket(
        &self,
        bucket: &str,
        constant: bool,
        noise: Option<&str>,
    ) -> Vec<AlgoScore> {
        let mut v: Vec<AlgoScore> = self
            .entries
            .iter()
            .filter(|e| e.bucket == bucket)
            .filter(|e| {
                constant
                    || !matches!(
                        e.algo,
                        SddeAlgorithm::Rma | SddeAlgorithm::LocalityRma
                    )
            })
            .map(|e| {
                let inflation = Self::inflation_of(e, noise);
                AlgoScore {
                    algo: e.algo,
                    base: e.base,
                    inflation,
                    cp_wait: e.cp_wait,
                    score: e.base * (1.0 + self.robustness * (inflation - 1.0)),
                }
            })
            .collect();
        v.sort_by(|a, b| {
            a.score
                .total_cmp(&b.score)
                .then(a.cp_wait.total_cmp(&b.cp_wait))
                .then(algo_rank(a.algo).cmp(&algo_rank(b.algo)))
        });
        v
    }

    /// Scored ranking for a pattern (best first), or empty when the
    /// bucket is uncovered.
    pub fn scores(&self, stats: &PatternStats, noise: Option<&str>) -> Vec<AlgoScore> {
        self.scores_for_bucket(&bucket_key(stats), stats.constant, noise)
    }

    /// Model-driven selection; `None` when the bucket has no entries
    /// (callers fall back to the heuristic — see [`select`]).
    pub fn select(&self, stats: &PatternStats, noise: Option<&str>) -> Option<Selection> {
        let bucket = bucket_key(stats);
        let scores = self.scores(stats, noise);
        let best = scores.first()?.clone();
        let regime = noise.unwrap_or("none");
        let mut rationale = format!(
            "model: bucket {bucket} under '{regime}' noise -> {} \
             (base {:.3}, inflation {:.3}, score {:.3}, cp-wait {:.0}%)",
            best.algo.name(),
            best.base,
            best.inflation,
            best.score,
            best.cp_wait * 100.0
        );
        if let Some(second) = scores.get(1) {
            rationale.push_str(&format!(
                "; runner-up {} (score {:.3})",
                second.algo.name(),
                second.score
            ));
        }
        Some(Selection {
            algo: best.algo,
            rationale,
            scores,
            source: SelectionSource::Model,
        })
    }

    /// Buckets the model carries entries for, in first-seen order.
    pub fn buckets(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for e in &self.entries {
            if !out.contains(&e.bucket) {
                out.push(e.bucket.clone());
            }
        }
        out
    }

    /// Decision table for one pattern: the robust pick per noise regime
    /// ("none" plus every calibrated profile), then the full score matrix.
    /// The `sdde dispatch` payload.
    pub fn decision_table(&self, stats: &PatternStats) -> String {
        let bucket = bucket_key(stats);
        let mut out = format!(
            "-- dispatch decision table: bucket {bucket} (robustness w={}) --\n",
            self.robustness
        );
        let none = self.select(stats, None);
        let Some(none) = none else {
            let fallback = heuristic(stats);
            out.push_str("(no calibrated entries for this bucket)\n");
            out.push_str(&format!(
                "heuristic fallback rationale: {} -> {}\n",
                fallback.rationale,
                fallback.algo.name()
            ));
            return out;
        };
        let mut rows = vec![vec![
            "noise".to_string(),
            "pick".to_string(),
            "score".to_string(),
            "note".to_string(),
        ]];
        rows.push(vec![
            "none".to_string(),
            none.algo.name().to_string(),
            format!("{:.3}", none.scores[0].score),
            String::new(),
        ]);
        let mut flipped: Vec<String> = Vec::new();
        for profile in &self.profiles {
            if let Some(sel) = self.select(stats, Some(profile)) {
                let note = if sel.algo != none.algo {
                    flipped.push(profile.clone());
                    format!("<- differs from fault-free ({})", none.algo.name())
                } else {
                    String::new()
                };
                rows.push(vec![
                    profile.clone(),
                    sel.algo.name().to_string(),
                    format!("{:.3}", sel.scores[0].score),
                    note,
                ]);
            }
        }
        out.push_str(&fmt::table(&rows));
        // Score matrix: one row per algorithm, one column per regime.
        out.push_str("\n-- calibrated scores: base x (1 + w*(inflation-1)), lower wins --\n");
        let mut matrix = vec![{
            let mut h = vec![
                "algo".to_string(),
                "base".to_string(),
                "cp-wait".to_string(),
                "none".to_string(),
            ];
            h.extend(self.profiles.iter().cloned());
            h
        }];
        let mut ranked = self.scores(stats, None);
        ranked.sort_by_key(|s| algo_rank(s.algo));
        for s in &ranked {
            let mut row = vec![
                s.algo.name().to_string(),
                format!("{:.3}", s.base),
                format!("{:.0}%", s.cp_wait * 100.0),
                format!("{:.3}", s.score),
            ];
            for profile in &self.profiles {
                let v = self
                    .scores(stats, Some(profile))
                    .into_iter()
                    .find(|x| x.algo == s.algo)
                    .map(|x| x.score)
                    .unwrap_or(f64::NAN);
                row.push(format!("{v:.3}"));
            }
            matrix.push(row);
        }
        out.push_str(&fmt::table(&matrix));
        out.push_str(&format!("rationale (fault-free): {}\n", none.rationale));
        for profile in &flipped {
            if let Some(sel) = self.select(stats, Some(profile)) {
                out.push_str(&format!("rationale ({profile}): {}\n", sel.rationale));
            }
        }
        out
    }

    /// One row per calibrated bucket: the fault-free pick and each
    /// profile's robust pick (`*` marks a flip). The `sdde calibrate`
    /// summary.
    pub fn summary_table(&self) -> String {
        let buckets = self.buckets();
        let mut out = format!(
            "-- calibrated dispatch model: {} bucket(s), {} profile(s), {} entries --\n",
            buckets.len(),
            self.profiles.len(),
            self.entries.len()
        );
        let mut rows = vec![{
            let mut h = vec!["bucket".to_string(), "none".to_string()];
            h.extend(self.profiles.iter().cloned());
            h
        }];
        for bucket in &buckets {
            let constant = bucket.ends_with("/crs");
            let none_pick = self
                .scores_for_bucket(bucket, constant, None)
                .first()
                .map(|s| s.algo);
            let mut row = vec![
                bucket.clone(),
                none_pick.map(|a| a.name().to_string()).unwrap_or_default(),
            ];
            for profile in &self.profiles {
                let pick = self
                    .scores_for_bucket(bucket, constant, Some(profile))
                    .first()
                    .map(|s| s.algo);
                row.push(match pick {
                    Some(a) if Some(a) != none_pick => format!("{}*", a.name()),
                    Some(a) => a.name().to_string(),
                    None => String::new(),
                });
            }
            rows.push(row);
        }
        out.push_str(&fmt::table(&rows));
        out.push_str("(* = robustness-weighted pick differs from fault-free ranking)\n");
        out
    }
}

/// The legacy three-branch heuristic, bit-for-bit (DESIGN.md invariant 9):
/// aggregation pays once per-rank sends exceed 2× the region size at 64+
/// ranks; otherwise NBX at 256+ ranks; otherwise personalized.
pub fn heuristic(stats: &PatternStats) -> Selection {
    let p = stats.nranks;
    let region = stats.region_size;
    let nnz = stats.send_nnz;
    let (algo, why) = if nnz > 2 * region && p >= 64 {
        (
            SddeAlgorithm::LocalityNonBlocking,
            format!("send_nnz {nnz} > 2x region {region} at {p} >= 64 ranks: aggregation pays"),
        )
    } else if p >= 256 {
        (
            SddeAlgorithm::NonBlocking,
            format!("{p} >= 256 ranks: the counts-allreduce dominates"),
        )
    } else {
        (
            SddeAlgorithm::Personalized,
            format!("{p} ranks, {nnz} destinations: the counts-allreduce is cheap"),
        )
    };
    Selection {
        algo,
        rationale: format!("heuristic: {why}"),
        scores: Vec::new(),
        source: SelectionSource::Heuristic,
    }
}

/// Resolve a `Dispatch` request: consult the model when one is loaded,
/// fall back to the legacy heuristic otherwise (also when the model has
/// no entries for the pattern's bucket).
pub fn select(
    model: Option<&DispatchModel>,
    stats: &PatternStats,
    noise: Option<&str>,
) -> Selection {
    if let Some(m) = model {
        if let Some(sel) = m.select(stats, noise) {
            return sel;
        }
        let mut sel = heuristic(stats);
        sel.rationale = format!(
            "no calibrated entries for bucket {}; {}",
            bucket_key(stats),
            sel.rationale
        );
        return sel;
    }
    heuristic(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(nranks: usize, region: usize, nnz: usize, constant: bool) -> PatternStats {
        PatternStats {
            nranks,
            region_size: region,
            send_nnz: nnz,
            local_frac: 0.0,
            constant,
        }
    }

    #[test]
    fn buckets_follow_the_heuristic_axes() {
        assert_eq!(bucket_key(&stats(8, 8, 3, true)), "small/sparse/crs");
        assert_eq!(bucket_key(&stats(63, 8, 17, false)), "small/dense/crsv");
        assert_eq!(bucket_key(&stats(64, 8, 16, true)), "mid/sparse/crs");
        assert_eq!(bucket_key(&stats(256, 8, 17, true)), "large/dense/crs");
    }

    #[test]
    fn heuristic_reproduces_legacy_thresholds() {
        // The three branches, including both strict boundaries.
        assert_eq!(heuristic(&stats(8, 4, 3, true)).algo, SddeAlgorithm::Personalized);
        assert_eq!(
            heuristic(&stats(64, 8, 17, true)).algo,
            SddeAlgorithm::LocalityNonBlocking
        );
        assert_eq!(heuristic(&stats(64, 8, 16, true)).algo, SddeAlgorithm::Personalized);
        assert_eq!(heuristic(&stats(256, 8, 4, true)).algo, SddeAlgorithm::NonBlocking);
        assert_eq!(heuristic(&stats(255, 8, 16, true)).algo, SddeAlgorithm::Personalized);
        let sel = heuristic(&stats(8, 4, 3, true));
        assert_eq!(sel.source, SelectionSource::Heuristic);
        assert!(sel.rationale.contains("heuristic"), "{}", sel.rationale);
    }

    #[test]
    fn embedded_model_parses_and_covers_all_buckets() {
        let m = DispatchModel::embedded();
        assert!(m.robustness > 0.0);
        assert!(m.profiles.len() >= 2);
        let buckets = m.buckets();
        for scale in ["small", "mid", "large"] {
            for density in ["sparse", "dense"] {
                for variant in ["crs", "crsv"] {
                    let key = format!("{scale}/{density}/{variant}");
                    assert!(buckets.contains(&key), "missing bucket {key}");
                }
            }
        }
        // crsv buckets must not carry RMA rows (paper §IV-C).
        for e in &m.entries {
            if e.bucket.ends_with("/crsv") {
                assert!(
                    !matches!(e.algo, SddeAlgorithm::Rma | SddeAlgorithm::LocalityRma),
                    "RMA entry in {}",
                    e.bucket
                );
            }
        }
    }

    #[test]
    fn variable_size_filters_rma_from_scores() {
        let m = DispatchModel::embedded();
        // Same scale/density, crs vs crsv: the crs ranking may contain
        // RMA, the crsv ranking never does.
        let sel = m.select(&stats(128, 8, 4, false), None).unwrap();
        for s in &sel.scores {
            assert!(
                !matches!(s.algo, SddeAlgorithm::Rma | SddeAlgorithm::LocalityRma),
                "{:?}",
                s.algo
            );
        }
    }

    #[test]
    fn uncovered_bucket_falls_back_to_heuristic() {
        let empty = DispatchModel {
            robustness: 1.0,
            profiles: vec!["heavy".into()],
            entries: vec![],
        };
        let st = stats(8, 4, 3, true);
        let sel = select(Some(&empty), &st, None);
        assert_eq!(sel.source, SelectionSource::Heuristic);
        assert_eq!(sel.algo, heuristic(&st).algo);
        assert!(sel.rationale.contains("no calibrated entries"), "{}", sel.rationale);
        // And the decision table still renders something grep-able.
        let table = empty.decision_table(&st);
        assert!(table.contains("decision table"), "{table}");
        assert!(table.contains("rationale"), "{table}");
    }

    #[test]
    fn decision_table_lists_all_regimes() {
        let m = DispatchModel::embedded();
        let table = m.decision_table(&stats(32, 8, 4, false));
        assert!(table.contains("decision table"), "{table}");
        for p in &m.profiles {
            assert!(table.contains(p.as_str()), "missing profile {p}:\n{table}");
        }
        assert!(table.contains("rationale (fault-free)"), "{table}");
    }

    #[test]
    fn summary_table_marks_flips() {
        let m = DispatchModel::embedded();
        let s = m.summary_table();
        assert!(s.contains("calibrated dispatch model"), "{s}");
        assert!(s.contains('*'), "expected at least one flip marker:\n{s}");
    }
}
