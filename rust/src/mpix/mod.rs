//! The paper's contribution: MPI Advance-style **sparse dynamic data
//! exchange** (SDDE) APIs and algorithms.
//!
//! Two entry points mirror the paper's Figures 3 & 4 (Table I variables map
//! to the fields of [`CrsArgs`]/[`CrsvArgs`] and [`CrsResult`]/[`CrsvResult`]):
//!
//! * [`alltoall_crs`] — constant-size SDDE (`MPIX_Alltoall_crs`): every
//!   message carries `sendcount` values; the receive side of the pattern is
//!   unknown. Use case: AMR remesh notification (CELLAR).
//! * [`alltoallv_crs`] — variable-size SDDE (`MPIX_Alltoallv_crs`): each
//!   message carries the indices the destination must later send; used to
//!   form sparse-matrix communication patterns (Hypre-style solvers).
//!
//! Five algorithms (paper §IV) are selected via [`MpixInfo::algorithm`]:
//! [`SddeAlgorithm::Personalized`] (Alg. 1), [`SddeAlgorithm::NonBlocking`]
//! (Alg. 2, Hoefler NBX), [`SddeAlgorithm::Rma`] (Alg. 3, constant-size
//! only), and the two novel locality-aware variants (Algs. 4 & 5) that
//! aggregate messages per region before the inter-region exchange.
//!
//! Results are returned in canonical order (ascending source rank) so that
//! all algorithms are directly comparable; MPI Advance returns arbitrary
//! order, which callers immediately canonicalize anyway when building
//! communication packages.

pub mod algos;
mod comm;
mod crs;
pub mod dispatch;
pub mod neighbor;

pub use comm::{IntraAlgo, MpixComm, MpixInfo};
pub use crs::{CrsArgs, CrsResult, CrsvArgs, CrsvResult};
pub use dispatch::{
    DispatchModel, ModelEntry, PatternStats, Selection, SelectionSource,
};
pub use neighbor::{NeighborAlltoallv, NeighborComm, NeighborExchange, NeighborMethod};

use anyhow::{bail, Result};

/// Algorithm selector (paper §IV). `Dispatch` picks a reasonable default
/// from problem statistics (future-work hook the paper calls for in §VI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SddeAlgorithm {
    /// Alg. 1: MPI_Allreduce on message counts, then dynamic probe/recv.
    Personalized,
    /// Alg. 2: NBX — synchronous sends, iprobe, non-blocking barrier.
    NonBlocking,
    /// Alg. 3: one-sided puts into a window (constant-size SDDE only).
    Rma,
    /// Alg. 4: locality-aware aggregation + personalized inter-region step.
    LocalityPersonalized,
    /// Alg. 5: locality-aware aggregation + NBX inter-region step.
    LocalityNonBlocking,
    /// Extension (paper §VI future work): locality-aware aggregation with
    /// one-sided puts (constant-size SDDE only).
    LocalityRma,
    /// Pick automatically from (nranks, send_nnz) — see §VI future work.
    Dispatch,
}

impl SddeAlgorithm {
    /// The paper's five algorithms (§IV).
    pub const ALL: [SddeAlgorithm; 5] = [
        SddeAlgorithm::Personalized,
        SddeAlgorithm::NonBlocking,
        SddeAlgorithm::Rma,
        SddeAlgorithm::LocalityPersonalized,
        SddeAlgorithm::LocalityNonBlocking,
    ];

    /// Everything valid for the constant-size SDDE (paper's five plus the
    /// locality-aware RMA extension).
    pub const CONST_SIZE: [SddeAlgorithm; 6] = [
        SddeAlgorithm::Personalized,
        SddeAlgorithm::NonBlocking,
        SddeAlgorithm::Rma,
        SddeAlgorithm::LocalityPersonalized,
        SddeAlgorithm::LocalityNonBlocking,
        SddeAlgorithm::LocalityRma,
    ];

    /// Algorithms valid for the variable-size SDDE (no RMA — paper §IV-C).
    pub const VARIABLE: [SddeAlgorithm; 4] = [
        SddeAlgorithm::Personalized,
        SddeAlgorithm::NonBlocking,
        SddeAlgorithm::LocalityPersonalized,
        SddeAlgorithm::LocalityNonBlocking,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SddeAlgorithm::Personalized => "personalized",
            SddeAlgorithm::NonBlocking => "nonblocking",
            SddeAlgorithm::Rma => "rma",
            SddeAlgorithm::LocalityPersonalized => "loc-personalized",
            SddeAlgorithm::LocalityNonBlocking => "loc-nonblocking",
            SddeAlgorithm::LocalityRma => "loc-rma",
            SddeAlgorithm::Dispatch => "dispatch",
        }
    }

    /// Parse a CLI spelling. The error message lists every valid name and
    /// alias — callers surface it verbatim instead of silently dropping
    /// unknown names.
    pub fn parse(s: &str) -> Result<SddeAlgorithm, String> {
        match s.to_ascii_lowercase().as_str() {
            "personalized" | "pers" => Ok(SddeAlgorithm::Personalized),
            "nonblocking" | "nbx" => Ok(SddeAlgorithm::NonBlocking),
            "rma" => Ok(SddeAlgorithm::Rma),
            "loc-personalized" | "locality-personalized" | "loc-pers" => {
                Ok(SddeAlgorithm::LocalityPersonalized)
            }
            "loc-nonblocking" | "locality-nonblocking" | "loc-nbx" => {
                Ok(SddeAlgorithm::LocalityNonBlocking)
            }
            "loc-rma" | "locality-rma" => Ok(SddeAlgorithm::LocalityRma),
            "dispatch" | "auto" => Ok(SddeAlgorithm::Dispatch),
            _ => Err(format!(
                "unknown SDDE algorithm '{s}' (valid: personalized|pers, \
                 nonblocking|nbx, rma, loc-personalized|loc-pers, \
                 loc-nonblocking|loc-nbx, loc-rma, dispatch|auto)"
            )),
        }
    }
}

/// `MPIX_Alltoall_crs`: constant-size sparse dynamic data exchange.
///
/// Every rank knows its send side (`args.dest`, `args.sendvals` with
/// `args.sendcount` values per destination) and learns its receive side:
/// which ranks sent to it and their values.
pub async fn alltoall_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsArgs) -> Result<CrsResult> {
    args.validate()?;
    let algo = select_algorithm(info, mx, &args.dest, true)?.algo;
    let mut out = match algo {
        SddeAlgorithm::Personalized => algos::personalized::alltoall_crs(mx, info, args).await,
        SddeAlgorithm::NonBlocking => algos::nonblocking::alltoall_crs(mx, info, args).await,
        SddeAlgorithm::Rma => algos::rma::alltoall_crs(mx, info, args).await,
        SddeAlgorithm::LocalityPersonalized => {
            algos::locality::alltoall_crs(mx, info, args, false).await
        }
        SddeAlgorithm::LocalityNonBlocking => {
            algos::locality::alltoall_crs(mx, info, args, true).await
        }
        SddeAlgorithm::LocalityRma => algos::locality_rma::alltoall_crs(mx, info, args).await,
        SddeAlgorithm::Dispatch => unreachable!("resolved above"),
    };
    out.canonicalize(args.sendcount);
    Ok(out)
}

/// `MPIX_Alltoallv_crs`: variable-size sparse dynamic data exchange.
pub async fn alltoallv_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsvArgs) -> Result<CrsvResult> {
    args.validate()?;
    let algo = select_algorithm(info, mx, &args.dest, false)?.algo;
    let mut out = match algo {
        SddeAlgorithm::Personalized => algos::personalized::alltoallv_crs(mx, info, args).await,
        SddeAlgorithm::NonBlocking => algos::nonblocking::alltoallv_crs(mx, info, args).await,
        SddeAlgorithm::Rma => bail!("RMA SDDE applies only to MPIX_Alltoall_crs (paper §IV-C)"),
        SddeAlgorithm::LocalityPersonalized => {
            algos::locality::alltoallv_crs(mx, info, args, false).await
        }
        SddeAlgorithm::LocalityNonBlocking => {
            algos::locality::alltoallv_crs(mx, info, args, true).await
        }
        SddeAlgorithm::LocalityRma => {
            bail!("locality-RMA applies only to MPIX_Alltoall_crs (constant-size)")
        }
        SddeAlgorithm::Dispatch => unreachable!("resolved above"),
    };
    out.canonicalize();
    Ok(out)
}

/// Resolve the algorithm for one SDDE call: validates RMA-on-variable for
/// explicit requests and resolves `Dispatch` through [`dispatch::select`]
/// — the evidence model when `info.dispatch_model` is loaded, the legacy
/// threshold heuristic (bit-identical picks) otherwise. Public so the
/// CLI, bench sweeps, and tests can report the pick *and its rationale*.
pub fn select_algorithm(
    info: &MpixInfo,
    mx: &MpixComm,
    dest: &[usize],
    constant: bool,
) -> Result<Selection> {
    let algo = info.algorithm;
    if algo != SddeAlgorithm::Dispatch {
        if (algo == SddeAlgorithm::Rma || algo == SddeAlgorithm::LocalityRma) && !constant {
            bail!("RMA SDDE applies only to MPIX_Alltoall_crs (paper §IV-C)");
        }
        return Ok(Selection::explicit(algo));
    }
    let stats = PatternStats::measure(mx, dest, constant);
    Ok(dispatch::select(
        info.dispatch_model.as_deref(),
        &stats,
        info.dispatch_noise.as_deref(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, RegionKind, Topology};

    fn mx_for(nodes: usize, ppn: usize) -> MpixComm {
        let w = World::new(
            Topology::quartz(nodes, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        MpixComm::new(w.comm(0), RegionKind::Node)
    }

    fn dispatch(mx: &MpixComm, send_nnz: usize) -> SddeAlgorithm {
        // MpixInfo::default() carries no model, so Dispatch resolves
        // through the legacy-equivalent heuristic.
        let dest: Vec<usize> = (0..send_nnz).map(|i| i % mx.comm.nranks()).collect();
        select_algorithm(&MpixInfo::default(), mx, &dest, true)
            .unwrap()
            .algo
    }

    #[test]
    fn dispatch_small_world_picks_personalized() {
        // 8 ranks, sparse sends: the allreduce is cheap — Personalized.
        assert_eq!(dispatch(&mx_for(2, 4), 3), SddeAlgorithm::Personalized);
    }

    #[test]
    fn dispatch_large_world_picks_nonblocking() {
        // 256 ranks, sparse sends: the allreduce dominates — NBX.
        assert_eq!(dispatch(&mx_for(32, 8), 4), SddeAlgorithm::NonBlocking);
    }

    #[test]
    fn dispatch_dense_sends_at_scale_pick_locality() {
        // 64 ranks (8/region) with > 2x-region destinations: aggregation
        // pays — LocalityNonBlocking.
        let mx = mx_for(8, 8);
        assert_eq!(dispatch(&mx, 17), SddeAlgorithm::LocalityNonBlocking);
        // ... but exactly at the 2x-region boundary it does not.
        assert_eq!(dispatch(&mx, 16), SddeAlgorithm::Personalized);
    }

    #[test]
    fn dispatch_dense_sends_below_scale_stay_standard() {
        // Dense sends on a tiny world (8 ranks < the 64-rank floor): the
        // aggregation detour is pure overhead.
        assert_eq!(dispatch(&mx_for(2, 4), 20), SddeAlgorithm::Personalized);
    }

    #[test]
    fn rma_on_variable_size_is_an_error() {
        // Paper §IV-C: the one-sided algorithms exist only for the
        // constant-size SDDE, even when requested explicitly.
        let mx = mx_for(2, 4);
        for algo in [SddeAlgorithm::Rma, SddeAlgorithm::LocalityRma] {
            let info = MpixInfo::with_algorithm(algo);
            let err = select_algorithm(&info, &mx, &[0, 1], false).unwrap_err();
            assert!(err.to_string().contains("MPIX_Alltoall_crs"), "{err}");
            // The constant-size path accepts the same request.
            let sel = select_algorithm(&info, &mx, &[0, 1], true).unwrap();
            assert_eq!(sel.algo, algo);
            assert_eq!(sel.source, SelectionSource::Explicit);
        }
    }

    #[test]
    fn parse_rejects_unknown_names_with_the_valid_list() {
        assert_eq!(SddeAlgorithm::parse("auto"), Ok(SddeAlgorithm::Dispatch));
        assert_eq!(
            SddeAlgorithm::parse("LOC-NBX"),
            Ok(SddeAlgorithm::LocalityNonBlocking)
        );
        let err = SddeAlgorithm::parse("gremlin").unwrap_err();
        for name in ["personalized", "nbx", "rma", "loc-nonblocking", "dispatch"] {
            assert!(err.contains(name), "missing '{name}' in: {err}");
        }
    }

    #[test]
    fn model_driven_dispatch_uses_the_loaded_evidence() {
        // 128 ranks, sparse, constant-size: the heuristic would say
        // Personalized (128 < 256, sends below 2x region), but the
        // embedded model knows RMA wins this bucket fault-free — and that
        // it collapses under jitter, flipping the pick to NBX.
        let mx = mx_for(16, 8);
        let mut info = MpixInfo::default();
        info.dispatch_model = Some(std::rc::Rc::new(DispatchModel::embedded().clone()));
        let dest = vec![0usize, 9, 17, 33];
        let sel = select_algorithm(&info, &mx, &dest, true).unwrap();
        assert_eq!(sel.source, SelectionSource::Model);
        assert_eq!(sel.algo, SddeAlgorithm::Rma);
        assert!(!sel.scores.is_empty());
        info.dispatch_noise = Some("jitter".to_string());
        let noisy = select_algorithm(&info, &mx, &dest, true).unwrap();
        assert_eq!(noisy.algo, SddeAlgorithm::NonBlocking);
    }
}
