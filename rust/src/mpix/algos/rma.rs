//! Algorithm 3 — the **RMA** constant-size SDDE (as implemented in CELLAR).
//!
//! Allocate a window with `nranks × sendcount` slots per rank; each process
//! `MPI_Put`s its `sendcount` values at offset `rank × sendcount` of every
//! destination's window; after a fence, each rank scans its window and
//! collects the slots that were written. No dynamic two-sided communication
//! (and no matching costs) at all — but two window synchronizations.
//!
//! Only valid for `MPIX_Alltoall_crs`: variable-size data cannot be placed
//! at statically-known offsets (paper §IV-C).

use std::rc::Rc;

use crate::mpix::{CrsArgs, CrsResult, MpixComm, MpixInfo};

/// Window slots are pre-filled with this sentinel; any other value marks a
/// received message. (User values must avoid it; the SDDE use case sends
/// message sizes / small indices, which never collide with `u64::MAX`.)
pub const SENTINEL: u64 = u64::MAX;

pub async fn alltoall_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsArgs) -> CrsResult {
    let c = &mx.comm;
    let n = c.nranks();
    let me = c.rank();
    let words = n * args.sendcount;

    // Window creation can be amortized across calls (paper §IV-C): reuse a
    // cached window when permitted and large enough.
    let win = {
        let cached = mx.cached_window.borrow().clone();
        match cached {
            Some(w) if info.reuse_rma_window && w.words() >= words => w,
            _ => {
                let w = Rc::new(c.win_allocate(words).await);
                *mx.cached_window.borrow_mut() = Some(w.clone());
                w
            }
        }
    };

    // Open the epoch with a clean window.
    win.fill_local(SENTINEL);
    c.charge_cpu((words as u64) / 8).await; // memset-ish cost
    win.fence().await;

    // One-sided puts: my values land at offset me*sendcount at each target.
    for i in 0..args.dest.len() {
        win.put(args.dest[i], me * args.sendcount, args.vals(i), 4).await;
    }
    win.fence().await;

    // Collect: scan all nranks slots for written entries.
    let data = win.read_local(0, words);
    c.charge_cpu(n as u64).await; // linear scan cost (~1 ns/slot)
    let mut src = Vec::new();
    let mut recvvals = Vec::new();
    for p in 0..n {
        let slot = &data[p * args.sendcount..(p + 1) * args.sendcount];
        if slot[0] != SENTINEL {
            src.push(p);
            recvvals.extend_from_slice(slot);
        }
    }
    CrsResult { src, recvvals }
}
