//! SDDE algorithm implementations (paper §IV).
//!
//! The constant-size (`MPIX_Alltoall_crs`) entry points for the
//! personalized, non-blocking and locality-aware algorithms are thin
//! wrappers over the variable-size implementations (a constant-size SDDE
//! *is* a variable SDDE whose counts all equal `sendcount`; only their wire
//! sizes differ, and those are identical too). RMA is constant-size only.

pub mod locality;
pub mod locality_rma;
pub mod nonblocking;
pub mod personalized;
pub mod rma;

use crate::mpi::{Comm, Tag};
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult};

/// User-tag family reserved for SDDE traffic (below `TAG_INTERNAL_BASE`, so
/// SDDE messages count as *user* messages in the figure counters — they are
/// the paper's red-dot metric).
pub(crate) const TAG_SDDE: Tag = 0x1000;

/// Per-call tag pair; every collective SDDE invocation gets fresh tags so
/// back-to-back exchanges cannot cross-talk.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SddeTags {
    /// Direct / inter-region data messages.
    pub data: Tag,
    /// Intra-region redistribution messages (locality-aware phase 2).
    pub intra: Tag,
}

/// How many SDDE calls one communicator can issue before its tag sequence
/// would wrap back onto tags still potentially in flight. The sequence is
/// per-context (each `dup`/`split` gets a fresh budget), so exhausting it
/// means 2048 collective exchanges on a *single* communicator — beyond
/// that, dup a new communicator rather than relying on wraparound.
pub(crate) const SDDE_CALL_BUDGET: u32 = 0x800;

pub(crate) fn alloc_tags(comm: &Comm) -> SddeTags {
    let seq = comm.next_seq(TAG_SDDE);
    // The modulo is a release-mode last resort: a wrapped tag can alias an
    // exchange from 2048 calls ago that is somehow still unmatched. Debug
    // builds refuse instead of silently risking cross-talk.
    debug_assert!(
        seq < SDDE_CALL_BUDGET,
        "SDDE tag budget exhausted on ctx {}: {seq} calls on one communicator \
         (budget {SDDE_CALL_BUDGET}); dup() a fresh communicator",
        comm.ctx(),
    );
    let base = TAG_SDDE + (seq % SDDE_CALL_BUDGET) * 4;
    SddeTags {
        data: base,
        intra: base + 1,
    }
}

/// View a constant-size SDDE as a variable one (counts all `sendcount`).
pub(crate) fn crs_as_crsv(args: &CrsArgs) -> CrsvArgs {
    CrsvArgs {
        dest: args.dest.clone(),
        sendcounts: vec![args.sendcount; args.dest.len()],
        sendvals: args.sendvals.clone(),
    }
}

/// Collapse a variable result whose counts are uniformly `sendcount` back
/// into a constant-size result.
pub(crate) fn crsv_as_crs(out: CrsvResult, sendcount: usize) -> CrsResult {
    debug_assert!(out.recvcounts.iter().all(|&c| c == sendcount));
    CrsResult {
        src: out.src,
        recvvals: out.recvvals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    fn world(ppn: usize) -> World {
        World::new(
            Topology::quartz(1, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        )
    }

    #[test]
    fn tag_budget_boundary_last_call_in_budget() {
        // Call 0x7FF (the last within the budget) still gets a distinct
        // tag block, 4 tags above call 0x7FE's.
        let out = world(1).run(|c| async move {
            for _ in 0..(SDDE_CALL_BUDGET - 1) {
                c.next_seq(TAG_SDDE);
            }
            alloc_tags(&c).data
        });
        assert_eq!(out.results[0], TAG_SDDE + (SDDE_CALL_BUDGET - 1) * 4);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SDDE tag budget exhausted")]
    fn tag_budget_overflow_panics_in_debug() {
        // Call 0x800 would wrap onto call 0's tags; debug builds refuse.
        world(1).run(|c| async move {
            for _ in 0..SDDE_CALL_BUDGET {
                c.next_seq(TAG_SDDE);
            }
            alloc_tags(&c);
        });
    }

    #[test]
    fn dup_comms_have_independent_tag_sequences() {
        let out = world(2).run(|c| async move {
            let a = c.dup().await;
            let b = c.dup().await;
            // Burn tags on `a`; `b` and the parent start fresh, and the
            // two dups hand out identical sequences independently.
            for _ in 0..5 {
                alloc_tags(&a);
            }
            (alloc_tags(&a).data, alloc_tags(&b).data, alloc_tags(&c).data)
        });
        assert_eq!(out.results[0], (TAG_SDDE + 5 * 4, TAG_SDDE, TAG_SDDE));
        assert_eq!(out.results[1], out.results[0]);
    }
}
