//! SDDE algorithm implementations (paper §IV).
//!
//! The constant-size (`MPIX_Alltoall_crs`) entry points for the
//! personalized, non-blocking and locality-aware algorithms are thin
//! wrappers over the variable-size implementations (a constant-size SDDE
//! *is* a variable SDDE whose counts all equal `sendcount`; only their wire
//! sizes differ, and those are identical too). RMA is constant-size only.

pub mod locality;
pub mod locality_rma;
pub mod nonblocking;
pub mod personalized;
pub mod rma;

use crate::mpi::{Comm, Tag};
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult};

/// User-tag family reserved for SDDE traffic (below `TAG_INTERNAL_BASE`, so
/// SDDE messages count as *user* messages in the figure counters — they are
/// the paper's red-dot metric).
pub(crate) const TAG_SDDE: Tag = 0x1000;

/// Per-call tag pair; every collective SDDE invocation gets fresh tags so
/// back-to-back exchanges cannot cross-talk.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SddeTags {
    /// Direct / inter-region data messages.
    pub data: Tag,
    /// Intra-region redistribution messages (locality-aware phase 2).
    pub intra: Tag,
}

pub(crate) fn alloc_tags(comm: &Comm) -> SddeTags {
    let seq = comm.next_seq(TAG_SDDE);
    let base = TAG_SDDE + (seq % 0x800) * 4;
    SddeTags {
        data: base,
        intra: base + 1,
    }
}

/// View a constant-size SDDE as a variable one (counts all `sendcount`).
pub(crate) fn crs_as_crsv(args: &CrsArgs) -> CrsvArgs {
    CrsvArgs {
        dest: args.dest.clone(),
        sendcounts: vec![args.sendcount; args.dest.len()],
        sendvals: args.sendvals.clone(),
    }
}

/// Collapse a variable result whose counts are uniformly `sendcount` back
/// into a constant-size result.
pub(crate) fn crsv_as_crs(out: CrsvResult, sendcount: usize) -> CrsResult {
    debug_assert!(out.recvcounts.iter().all(|&c| c == sendcount));
    CrsResult {
        src: out.src,
        recvvals: out.recvvals,
    }
}
