//! Algorithms 4 & 5 — the paper's novel **locality-aware** SDDEs.
//!
//! Both algorithms concatenate every message destined to the same *region*
//! (node or socket) into one aggregated buffer, send each buffer to the
//! *corresponding process* of the destination region (the rank there with
//! the sender's local rank), and then redistribute within the region. This
//! trades one aggregated inter-region message for what would have been many
//! — directly attacking the inter-node message-count bottleneck.
//!
//! * Algorithm 4 (`nbx = false`): the inter-region step uses the
//!   personalized protocol (allreduce on counts + dynamic probe/recv).
//! * Algorithm 5 (`nbx = true`): the inter-region step uses NBX
//!   (synchronous sends + iprobe + non-blocking barrier).
//!
//! The intra-region phase is the personalized protocol in the paper
//! (regions are small and dense); [`crate::mpix::IntraAlgo::Alltoallv`]
//! switches it to a dense alltoallv as an ablation.
//!
//! Wire format of an aggregated buffer: a sequence of records
//! `[final_dest, origin, count, vals…]`, all 4-byte integers on the wire —
//! only *concatenation*, no dedup, per the paper (dedup overhead would
//! outweigh its benefit for a single exchange).

use std::collections::BTreeMap;

use crate::mpi::wait::all_done_signal;
use crate::mpi::{waitall, Payload, ReduceOp, WaitAny, ANY_SOURCE};
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult, IntraAlgo, MpixComm, MpixInfo};

use super::{alloc_tags, crs_as_crsv, crsv_as_crs, SddeTags};

/// Append a record to a regional aggregation buffer.
pub(crate) fn push_record(buf: &mut Vec<u64>, final_dest: usize, origin: usize, vals: &[u64]) {
    buf.push(final_dest as u64);
    buf.push(origin as u64);
    buf.push(vals.len() as u64);
    buf.extend_from_slice(vals);
}

/// Split an aggregated buffer back into its records.
fn unpack_records(buf: &[u64]) -> Vec<(usize, usize, Vec<u64>)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < buf.len() {
        let final_dest = buf[i] as usize;
        let origin = buf[i + 1] as usize;
        let count = buf[i + 2] as usize;
        out.push((final_dest, origin, buf[i + 3..i + 3 + count].to_vec()));
        i += 3 + count;
    }
    out
}

pub async fn alltoallv_crs(
    mx: &MpixComm,
    info: &MpixInfo,
    args: &CrsvArgs,
    nbx: bool,
) -> CrsvResult {
    let c = &mx.comm;
    let me = c.rank();
    let tags = alloc_tags(c);

    // ---- Phase 0: aggregate messages by destination region. -------------
    let mut bufs: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    let mut pack_words = 0u64;
    for i in 0..args.dest.len() {
        let d = args.dest[i];
        let vals = args.vals(i);
        push_record(bufs.entry(mx.region(d)).or_default(), d, me, vals);
        pack_words += 3 + vals.len() as u64;
    }
    // Packing cost: ~0.25 ns/word (streaming copy).
    c.charge_cpu(pack_words / 4).await;

    // Records bound for my own region skip the wire.
    let my_region = mx.my_region();
    let mut local_bufs: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    let mut pairs: Vec<(usize, Vec<u64>)> = Vec::new();
    if let Some(own) = bufs.remove(&my_region) {
        scatter_records(&own, me, &mut local_bufs, &mut pairs);
    }

    // ---- Phase 1: inter-region exchange to corresponding ranks. ---------
    let incoming: Vec<Vec<u64>> = if nbx {
        inter_nbx(mx, &bufs, tags).await
    } else {
        inter_personalized(mx, &bufs, tags).await
    };
    for buf in &incoming {
        scatter_records(buf, me, &mut local_bufs, &mut pairs);
    }

    // ---- Phase 2: intra-region redistribution. ---------------------------
    match info.intra {
        IntraAlgo::Personalized => {
            intra_personalized_crs(mx, local_bufs, tags, &mut pairs).await;
        }
        IntraAlgo::Alltoallv => {
            intra_alltoallv(mx, local_bufs, &mut pairs).await;
        }
    }

    CrsvResult::from_pairs(pairs)
}

/// Route unpacked records either to this rank's results (final dest == me)
/// or into the per-local-process phase-2 buffers `[origin, count, vals…]`.
fn scatter_records(
    buf: &[u64],
    me: usize,
    local_bufs: &mut BTreeMap<usize, Vec<u64>>,
    pairs: &mut Vec<(usize, Vec<u64>)>,
) {
    for (final_dest, origin, vals) in unpack_records(buf) {
        if final_dest == me {
            pairs.push((origin, vals));
        } else {
            push_record(local_bufs.entry(final_dest).or_default(), final_dest, origin, &vals);
        }
    }
}

/// Inter-region step, personalized flavor (Algorithm 4): allreduce on
/// aggregated-message counts, then dynamic probe/recv.
async fn inter_personalized(
    mx: &MpixComm,
    bufs: &BTreeMap<usize, Vec<u64>>,
    tags: SddeTags,
) -> Vec<Vec<u64>> {
    let c = &mx.comm;
    let n = c.nranks();
    let mut reqs = Vec::with_capacity(bufs.len());
    let mut msg_count = vec![0u64; n];
    for (&region, buf) in bufs {
        let corr = mx.corresponding_rank(region);
        msg_count[corr] = 1;
        reqs.push(c.isend(corr, tags.data, Payload::ints(buf)).await);
    }
    let n_recv = c.allreduce(msg_count, ReduceOp::Sum).await[c.rank()] as usize;
    let mut incoming = Vec::with_capacity(n_recv);
    for _ in 0..n_recv {
        let m = c.probe_recv(ANY_SOURCE, tags.data).await;
        incoming.push(m.payload.words);
    }
    waitall(&reqs).await;
    incoming
}

/// Inter-region step, NBX flavor (Algorithm 5): synchronous sends of the
/// aggregated buffers, iprobe + recv, non-blocking barrier to terminate.
async fn inter_nbx(
    mx: &MpixComm,
    bufs: &BTreeMap<usize, Vec<u64>>,
    tags: SddeTags,
) -> Vec<Vec<u64>> {
    let c = &mx.comm;
    let mut reqs = Vec::with_capacity(bufs.len());
    for (&region, buf) in bufs {
        let corr = mx.corresponding_rank(region);
        reqs.push(c.issend(corr, tags.data, Payload::ints(buf)).await);
    }
    let sends_done = all_done_signal(&reqs);
    let mut incoming = Vec::new();
    let mut barrier: Option<crate::mpi::IBarrier> = None;
    loop {
        let epoch = c.arrival_epoch();
        if let Some(pi) = c.iprobe(ANY_SOURCE, tags.data).await {
            let m = c.recv(pi.src, pi.tag).await;
            incoming.push(m.payload.words);
            continue;
        }
        match &barrier {
            Some(b) => {
                if b.is_done() {
                    break;
                }
                WaitAny::new(c, &[b.signal()]).with_epoch(epoch).await;
            }
            None => {
                if sends_done.is_set() {
                    barrier = Some(c.ibarrier().await);
                } else {
                    WaitAny::new(c, &[&sends_done]).with_epoch(epoch).await;
                }
            }
        }
    }
    incoming
}

/// Intra-region redistribution, personalized flavor (the paper's phase 2
/// in both Algorithms 4 and 5): allreduce on counts across the world, then
/// dynamic probe/recv within the region.
pub(crate) async fn intra_personalized_crs(
    mx: &MpixComm,
    local_bufs: BTreeMap<usize, Vec<u64>>,
    tags: SddeTags,
    pairs: &mut Vec<(usize, Vec<u64>)>,
) {
    let c = &mx.comm;
    let n = c.nranks();
    let mut reqs = Vec::with_capacity(local_bufs.len());
    let mut msg_count = vec![0u64; n];
    for (&proc, buf) in &local_bufs {
        debug_assert_ne!(proc, c.rank());
        msg_count[proc] = 1;
        reqs.push(c.isend(proc, tags.intra, Payload::ints(buf)).await);
    }
    let n_recv = c.allreduce(msg_count, ReduceOp::Sum).await[c.rank()] as usize;
    for _ in 0..n_recv {
        let m = c.probe_recv(ANY_SOURCE, tags.intra).await;
        for (final_dest, origin, vals) in unpack_records(&m.payload.words) {
            debug_assert_eq!(final_dest, c.rank());
            pairs.push((origin, vals));
        }
    }
    waitall(&reqs).await;
}

/// Intra-region redistribution via a dense alltoallv among the region's
/// ranks (ablation; paper §IV-D suggests it for wide nodes).
async fn intra_alltoallv(
    mx: &MpixComm,
    local_bufs: BTreeMap<usize, Vec<u64>>,
    pairs: &mut Vec<(usize, Vec<u64>)>,
) {
    let c = &mx.comm;
    let me = c.rank();
    // Dense exchange over the *world* would be wasteful; emulate a regional
    // alltoallv with direct sends + a count exchange implemented as a
    // regional gather of counts through point-to-point messages.
    // Since every rank of the region participates, use the world alltoallv
    // restricted to region members (empty buffers elsewhere).
    let n = c.nranks();
    let mut sendbufs = vec![Vec::new(); n];
    for (proc, buf) in local_bufs {
        sendbufs[proc] = buf;
    }
    let region_ranks: Vec<usize> = mx.region_ranks(mx.my_region()).to_vec();
    let out = regional_alltoallv(c, &region_ranks, sendbufs).await;
    for (src, buf) in out {
        debug_assert_ne!(src, me);
        for (final_dest, origin, vals) in unpack_records(&buf) {
            debug_assert_eq!(final_dest, me);
            pairs.push((origin, vals));
        }
    }
}

/// Dense alltoallv among `members` only (every member sends to every other
/// member, possibly an empty buffer).
async fn regional_alltoallv(
    c: &crate::mpi::Comm,
    members: &[usize],
    sendbufs: Vec<Vec<u64>>,
) -> Vec<(usize, Vec<u64>)> {
    let me = c.rank();
    let tags = alloc_tags(c);
    let mut reqs = Vec::new();
    for &dst in members {
        if dst != me {
            reqs.push(c.isend(dst, tags.intra, Payload::ints(&sendbufs[dst])).await);
        }
    }
    let mut out = Vec::new();
    for _ in 0..members.len() - 1 {
        let m = c.probe_recv(ANY_SOURCE, tags.intra).await;
        if !m.payload.words.is_empty() {
            out.push((m.src, m.payload.words));
        }
    }
    waitall(&reqs).await;
    out
}

pub async fn alltoall_crs(
    mx: &MpixComm,
    info: &MpixInfo,
    args: &CrsArgs,
    nbx: bool,
) -> CrsResult {
    let v = crs_as_crsv(args);
    let out = alltoallv_crs(mx, info, &v, nbx).await;
    crsv_as_crs(out, args.sendcount)
}
