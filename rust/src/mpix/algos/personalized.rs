//! Algorithm 1 — the **personalized** SDDE.
//!
//! 1. Build a `sizes` vector with one slot per rank, marking each
//!    destination; `MPI_Allreduce(SUM)` gives every rank the number of
//!    messages it will receive.
//! 2. Post a non-blocking send per destination.
//! 3. Dynamically receive exactly `sizes[rank]` messages via probe + recv.
//!
//! The allreduce overhead grows with the process count, but lets all
//! receive structures be counted up front (paper §IV-A).

use crate::mpi::{waitall, Payload, ReduceOp, ANY_SOURCE};
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult, MpixComm, MpixInfo};

use super::{alloc_tags, crs_as_crsv, crsv_as_crs};

pub async fn alltoallv_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsvArgs) -> CrsvResult {
    let c = &mx.comm;
    let tags = alloc_tags(c);
    let n = c.nranks();

    // Post all sends up front (non-blocking standard sends).
    let mut reqs = Vec::with_capacity(args.dest.len());
    let mut msg_count = vec![0u64; n];
    for i in 0..args.dest.len() {
        let d = args.dest[i];
        msg_count[d] = 1;
        reqs.push(c.isend(d, tags.data, Payload::ints(args.vals(i))).await);
    }

    // How many messages will I receive? (allreduce unless the caller knows)
    let n_recv = match info.known_recv_nnz {
        Some(k) => k,
        None => c.allreduce(msg_count, ReduceOp::Sum).await[c.rank()] as usize,
    };

    // Dynamically receive them.
    let mut pairs = Vec::with_capacity(n_recv);
    for _ in 0..n_recv {
        let m = c.probe_recv(ANY_SOURCE, tags.data).await;
        pairs.push((m.src, m.payload.words));
    }
    waitall(&reqs).await;
    CrsvResult::from_pairs(pairs)
}

pub async fn alltoall_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsArgs) -> CrsResult {
    let v = crs_as_crsv(args);
    let out = alltoallv_crs(mx, info, &v).await;
    crsv_as_crs(out, args.sendcount)
}
