//! **Extension** (paper §VI future work): locality-aware **RMA** SDDE.
//!
//! > "While this paper did not explore locality-aware aggregation for the
//! > RMA method, similar concatenation strategies could be used within
//! > MPI_Puts to reduce the synchronization overheads as well as
//! > communication costs."
//!
//! Constant-size only (like Algorithm 3). Every rank aggregates its
//! messages per destination region and `MPI_Put`s one buffer into a
//! *fixed slot* (indexed by origin rank) of the corresponding process's
//! window — so the put offsets stay statically known even though the
//! aggregated payload length varies (the slot is sized for the worst case,
//! region_size records). After one fence, the corresponding processes
//! unpack the records and redistribute within their region with the
//! personalized protocol, exactly like Algorithms 4/5's phase 2.
//!
//! Slot layout per origin: `[nrec, (final_dest, vals[sendcount])…]`,
//! `nrec == SENTINEL` meaning "no buffer from this origin".

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::mpix::{CrsArgs, CrsResult, MpixComm, MpixInfo};

use super::locality::{intra_personalized_crs, push_record};
use super::{alloc_tags, rma::SENTINEL};

pub async fn alltoall_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsArgs) -> CrsResult {
    let c = &mx.comm;
    let n = c.nranks();
    let me = c.rank();
    let tags = alloc_tags(c);
    let sc = args.sendcount;

    // Worst-case records per aggregated buffer: one per rank of the
    // largest region.
    let max_region = (0..mx.nregions())
        .map(|r| mx.region_ranks(r).len())
        .max()
        .unwrap_or(1);
    let slot = 1 + max_region * (1 + sc);
    let words = n * slot;

    // ---- Phase 0: aggregate by destination region (records carry only
    // final_dest + values; the origin is implied by the slot index). -----
    let mut bufs: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    for i in 0..args.dest.len() {
        let d = args.dest[i];
        let b = bufs.entry(mx.region(d)).or_default();
        b.push(d as u64);
        b.extend_from_slice(args.vals(i));
    }
    c.charge_cpu(args.sendvals.len() as u64 / 4).await;

    // ---- Phase 1: one-sided exchange of aggregated buffers. -------------
    let win = {
        let cached = mx.cached_window.borrow().clone();
        match cached {
            Some(w) if info.reuse_rma_window && w.words() >= words => w,
            _ => {
                let w = Rc::new(c.win_allocate(words).await);
                *mx.cached_window.borrow_mut() = Some(w.clone());
                w
            }
        }
    };
    win.fill_local(SENTINEL);
    c.charge_cpu((words as u64) / 8).await;
    win.fence().await;
    for (&region, buf) in &bufs {
        let corr = mx.corresponding_rank(region);
        let nrec = (buf.len() / (1 + sc)) as u64;
        let mut payload = Vec::with_capacity(1 + buf.len());
        payload.push(nrec);
        payload.extend_from_slice(buf);
        win.put(corr, me * slot, &payload, 4).await;
    }
    win.fence().await;

    // ---- Unpack: records for me → results; others → phase-2 buffers. ----
    let data = win.read_local(0, words);
    c.charge_cpu(n as u64).await;
    let mut pairs: Vec<(usize, Vec<u64>)> = Vec::new();
    let mut local_bufs: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    for origin in 0..n {
        let base = origin * slot;
        let nrec = data[base];
        if nrec == SENTINEL {
            continue;
        }
        let mut i = base + 1;
        for _ in 0..nrec {
            let final_dest = data[i] as usize;
            let vals = &data[i + 1..i + 1 + sc];
            if final_dest == me {
                pairs.push((origin, vals.to_vec()));
            } else {
                push_record(local_bufs.entry(final_dest).or_default(), final_dest, origin, vals);
            }
            i += 1 + sc;
        }
    }

    // ---- Phase 2: intra-region redistribution (personalized). -----------
    intra_personalized_crs(mx, local_bufs, tags, &mut pairs).await;

    pairs.sort_by_key(|&(s, _)| s);
    let mut out = CrsResult::default();
    for (s, v) in pairs {
        out.src.push(s);
        out.recvvals.extend_from_slice(&v);
    }
    out
}
