//! Algorithm 2 — the **non-blocking** SDDE (Hoefler/Siebert/Lumsdaine NBX).
//!
//! Synchronous sends to every destination; dynamically receive whatever
//! arrives (iprobe) while testing the sends; once all local sends have been
//! matched, enter a non-blocking barrier and keep receiving until the
//! barrier completes — at which point every rank's sends have been received
//! globally. Avoids the allreduce entirely (paper §IV-B).

use crate::mpi::wait::all_done_signal;
use crate::mpi::{Payload, WaitAny, ANY_SOURCE};
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult, MpixComm, MpixInfo};

use super::{alloc_tags, crs_as_crsv, crsv_as_crs};

pub async fn alltoallv_crs(mx: &MpixComm, _info: &MpixInfo, args: &CrsvArgs) -> CrsvResult {
    let c = &mx.comm;
    let tags = alloc_tags(c);

    // Synchronous sends: complete only when the destination matches.
    let mut reqs = Vec::with_capacity(args.dest.len());
    for i in 0..args.dest.len() {
        reqs.push(
            c.issend(args.dest[i], tags.data, Payload::ints(args.vals(i)))
                .await,
        );
    }

    let sends_done = all_done_signal(&reqs);
    let mut pairs = Vec::new();
    let mut barrier: Option<crate::mpi::IBarrier> = None;
    loop {
        // Dynamically receive anything available (the epoch sample keeps
        // arrivals racing the probe from being lost by the wait below).
        let epoch = c.arrival_epoch();
        if let Some(pi) = c.iprobe(ANY_SOURCE, tags.data).await {
            let m = c.recv(pi.src, pi.tag).await;
            pairs.push((m.src, m.payload.words));
            continue;
        }
        match &barrier {
            Some(b) => {
                if b.is_done() {
                    break;
                }
                WaitAny::new(c, &[b.signal()]).with_epoch(epoch).await;
            }
            None => {
                if sends_done.is_set() {
                    barrier = Some(c.ibarrier().await);
                } else {
                    WaitAny::new(c, &[&sends_done]).with_epoch(epoch).await;
                }
            }
        }
    }
    CrsvResult::from_pairs(pairs)
}

pub async fn alltoall_crs(mx: &MpixComm, info: &MpixInfo, args: &CrsArgs) -> CrsResult {
    let v = crs_as_crsv(args);
    let out = alltoallv_crs(mx, info, &v).await;
    crsv_as_crs(out, args.sendcount)
}
