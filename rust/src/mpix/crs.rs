//! Argument/result structs for the two SDDE APIs — the rust rendering of
//! the paper's Table I. `dest`/`sendcounts`/`sdispls`/`sendvals` become
//! `CrsArgs`/`CrsvArgs`; the output pointers become owned result structs
//! (`src`, `recvcounts`, `rdispls`, `recvvals`).

use anyhow::{ensure, Result};

/// Send side of `MPIX_Alltoall_crs` (constant size): `sendcount` values go
/// to each destination; `sendvals[i*sendcount..(i+1)*sendcount]` belongs to
/// `dest[i]`.
#[derive(Clone, Debug, Default)]
pub struct CrsArgs {
    pub dest: Vec<usize>,
    pub sendcount: usize,
    pub sendvals: Vec<u64>,
}

impl CrsArgs {
    /// The paper's headline use: one integer (a future message size) per
    /// destination.
    pub fn sizes(dest_sizes: &[(usize, u64)]) -> CrsArgs {
        CrsArgs {
            dest: dest_sizes.iter().map(|&(d, _)| d).collect(),
            sendcount: 1,
            sendvals: dest_sizes.iter().map(|&(_, s)| s).collect(),
        }
    }

    pub fn send_nnz(&self) -> usize {
        self.dest.len()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.sendcount > 0, "sendcount must be positive");
        ensure!(
            self.sendvals.len() == self.dest.len() * self.sendcount,
            "sendvals length {} != send_nnz {} x sendcount {}",
            self.sendvals.len(),
            self.dest.len(),
            self.sendcount
        );
        let mut seen = std::collections::HashSet::new();
        for &d in &self.dest {
            ensure!(seen.insert(d), "duplicate destination {d}");
        }
        Ok(())
    }

    /// Values for destination index `i`.
    pub fn vals(&self, i: usize) -> &[u64] {
        &self.sendvals[i * self.sendcount..(i + 1) * self.sendcount]
    }
}

/// Receive side of `MPIX_Alltoall_crs`: `recvvals[i*sendcount..]` came from
/// `src[i]`. Canonical order: ascending `src`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CrsResult {
    pub src: Vec<usize>,
    pub recvvals: Vec<u64>,
}

impl CrsResult {
    pub fn recv_nnz(&self) -> usize {
        self.src.len()
    }

    /// Sort by source rank (stable canonical form for comparisons).
    pub fn canonicalize(&mut self, sendcount: usize) {
        let mut idx: Vec<usize> = (0..self.src.len()).collect();
        idx.sort_by_key(|&i| self.src[i]);
        let src = idx.iter().map(|&i| self.src[i]).collect();
        let mut vals = Vec::with_capacity(self.recvvals.len());
        for &i in &idx {
            vals.extend_from_slice(&self.recvvals[i * sendcount..(i + 1) * sendcount]);
        }
        self.src = src;
        self.recvvals = vals;
    }
}

/// Send side of `MPIX_Alltoallv_crs` (variable size): `sendcounts[i]`
/// values go to `dest[i]`; `sendvals` is the concatenation (displacements
/// are implicit — prefix sums of `sendcounts`).
#[derive(Clone, Debug, Default)]
pub struct CrsvArgs {
    pub dest: Vec<usize>,
    pub sendcounts: Vec<usize>,
    pub sendvals: Vec<u64>,
}

impl CrsvArgs {
    pub fn send_nnz(&self) -> usize {
        self.dest.len()
    }

    pub fn send_size(&self) -> usize {
        self.sendvals.len()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.dest.len() == self.sendcounts.len(),
            "dest/sendcounts length mismatch"
        );
        let total: usize = self.sendcounts.iter().sum();
        ensure!(
            total == self.sendvals.len(),
            "sendvals length {} != sum(sendcounts) {}",
            self.sendvals.len(),
            total
        );
        ensure!(
            self.sendcounts.iter().all(|&c| c > 0),
            "zero-sized message (omit the destination instead)"
        );
        let mut seen = std::collections::HashSet::new();
        for &d in &self.dest {
            ensure!(seen.insert(d), "duplicate destination {d}");
        }
        Ok(())
    }

    /// Values for destination index `i`.
    pub fn vals(&self, i: usize) -> &[u64] {
        let start: usize = self.sendcounts[..i].iter().sum();
        &self.sendvals[start..start + self.sendcounts[i]]
    }
}

/// Receive side of `MPIX_Alltoallv_crs`. Canonical order: ascending `src`;
/// `rdispls` are the prefix sums of `recvcounts`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CrsvResult {
    pub src: Vec<usize>,
    pub recvcounts: Vec<usize>,
    pub rdispls: Vec<usize>,
    pub recvvals: Vec<u64>,
}

impl CrsvResult {
    pub fn recv_nnz(&self) -> usize {
        self.src.len()
    }

    pub fn recv_size(&self) -> usize {
        self.recvvals.len()
    }

    /// Values received from `src[i]`.
    pub fn vals(&self, i: usize) -> &[u64] {
        &self.recvvals[self.rdispls[i]..self.rdispls[i] + self.recvcounts[i]]
    }

    /// Build from per-source buffers (helper for the algorithm impls).
    pub fn from_pairs(mut pairs: Vec<(usize, Vec<u64>)>) -> CrsvResult {
        pairs.sort_by_key(|&(s, _)| s);
        let mut out = CrsvResult::default();
        for (s, v) in pairs {
            out.src.push(s);
            out.recvcounts.push(v.len());
            out.rdispls.push(out.recvvals.len());
            out.recvvals.extend_from_slice(&v);
        }
        out
    }

    /// Sort by source rank (stable canonical form for comparisons).
    pub fn canonicalize(&mut self) {
        let mut idx: Vec<usize> = (0..self.src.len()).collect();
        idx.sort_by_key(|&i| self.src[i]);
        let mut out = CrsvResult::default();
        for &i in &idx {
            out.src.push(self.src[i]);
            out.recvcounts.push(self.recvcounts[i]);
            out.rdispls.push(out.recvvals.len());
            out.recvvals
                .extend_from_slice(&self.recvvals[self.rdispls[i]..self.rdispls[i] + self.recvcounts[i]]);
        }
        *self = out;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crs_args_validate() {
        assert!(CrsArgs {
            dest: vec![1, 2],
            sendcount: 2,
            sendvals: vec![1, 2, 3, 4],
        }
        .validate()
        .is_ok());
        assert!(CrsArgs {
            dest: vec![1, 1],
            sendcount: 1,
            sendvals: vec![1, 2],
        }
        .validate()
        .is_err());
        assert!(CrsArgs {
            dest: vec![1],
            sendcount: 2,
            sendvals: vec![1],
        }
        .validate()
        .is_err());
    }

    #[test]
    fn crsv_args_validate_and_vals() {
        let a = CrsvArgs {
            dest: vec![3, 5],
            sendcounts: vec![2, 3],
            sendvals: vec![10, 11, 20, 21, 22],
        };
        a.validate().unwrap();
        assert_eq!(a.vals(0), &[10, 11]);
        assert_eq!(a.vals(1), &[20, 21, 22]);
        assert!(CrsvArgs {
            dest: vec![3],
            sendcounts: vec![0],
            sendvals: vec![],
        }
        .validate()
        .is_err());
    }

    #[test]
    fn crs_result_canonicalize() {
        let mut r = CrsResult {
            src: vec![5, 2, 9],
            recvvals: vec![50, 51, 20, 21, 90, 91],
        };
        r.canonicalize(2);
        assert_eq!(r.src, vec![2, 5, 9]);
        assert_eq!(r.recvvals, vec![20, 21, 50, 51, 90, 91]);
    }

    #[test]
    fn crsv_result_from_pairs_and_vals() {
        let r = CrsvResult::from_pairs(vec![(7, vec![70]), (1, vec![10, 11])]);
        assert_eq!(r.src, vec![1, 7]);
        assert_eq!(r.recvcounts, vec![2, 1]);
        assert_eq!(r.rdispls, vec![0, 2]);
        assert_eq!(r.vals(0), &[10, 11]);
        assert_eq!(r.vals(1), &[70]);
    }
}
