//! `MPIX_Comm` and `MPIX_Info` — the extension-library communicator (with
//! region/local-rank topology pre-computed, mirroring MPI Advance's
//! `MPIX_Comm_topo_init`) and the hint object that selects algorithms.

use std::cell::RefCell;
use std::rc::Rc;

use super::dispatch::DispatchModel;
use super::SddeAlgorithm;
use crate::mpi::{Comm, Window};
use crate::simnet::RegionKind;
use crate::util::FxHashMap;

/// Intra-region redistribution strategy for the locality-aware algorithms
/// (paper §IV-D discusses personalized vs. a dense alltoallv as future
/// optimization; we implement both as an ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntraAlgo {
    /// Allreduce on counts + dynamic probe/recv (the paper's choice).
    Personalized,
    /// Dense `MPI_Alltoallv` within the region.
    Alltoallv,
}

/// Hints controlling algorithm selection and behaviour — the analog of the
/// paper's `MPIX_Info`.
#[derive(Clone, Debug)]
pub struct MpixInfo {
    pub algorithm: SddeAlgorithm,
    /// Aggregation-region granularity for the locality-aware algorithms.
    pub region: RegionKind,
    /// Intra-region redistribution strategy.
    pub intra: IntraAlgo,
    /// If the caller already knows how many messages it will receive, the
    /// personalized algorithms can skip the allreduce (recv_nnz is
    /// input/output in the paper's API).
    pub known_recv_nnz: Option<usize>,
    /// Reuse the RMA window across calls (paper: window creation "can be
    /// amortized over the cost of the application").
    pub reuse_rma_window: bool,
    /// Calibrated evidence model consulted when `algorithm == Dispatch`.
    /// `None` (the default) falls back to the legacy threshold heuristic —
    /// bit-identical picks to the pre-model `resolve()` (DESIGN.md
    /// invariant 9).
    pub dispatch_model: Option<Rc<DispatchModel>>,
    /// Expected noise regime for model-driven dispatch: a fault-profile
    /// name from the model's calibration. `None` ranks fault-free.
    pub dispatch_noise: Option<String>,
}

impl Default for MpixInfo {
    fn default() -> Self {
        MpixInfo {
            algorithm: SddeAlgorithm::Dispatch,
            region: RegionKind::Node,
            intra: IntraAlgo::Personalized,
            known_recv_nnz: None,
            reuse_rma_window: true,
            dispatch_model: None,
            dispatch_noise: None,
        }
    }
}

impl MpixInfo {
    pub fn with_algorithm(algorithm: SddeAlgorithm) -> MpixInfo {
        MpixInfo {
            algorithm,
            ..MpixInfo::default()
        }
    }
}

/// Extension communicator: wraps an [`Comm`] plus cached region topology
/// (the `MPIX_Comm` of the paper, which caches shared-memory subcommunicators
/// in MPI Advance).
pub struct MpixComm {
    pub comm: Comm,
    region_kind: RegionKind,
    /// Region id of every rank.
    region_of: Vec<usize>,
    /// Local rank of every rank within its region.
    local_rank: Vec<usize>,
    /// Ranks of each region, ascending.
    region_ranks: Vec<Vec<usize>>,
    /// Cached RMA window (lazily allocated; reused across SDDE calls when
    /// `MpixInfo::reuse_rma_window` is set).
    pub(crate) cached_window: RefCell<Option<Rc<Window>>>,
}

impl MpixComm {
    /// Build from any communicator at `region` granularity. All rank ids
    /// here are comm-local; the machine topology is consulted through
    /// `to_world`, and region ids are densely re-indexed by first
    /// appearance among the members (a split communicator may touch only a
    /// subset of the machine, but the algorithms want contiguous region
    /// ids `0..nregions`). On the world communicator this reproduces the
    /// topology's own numbering exactly — regions and local ranks are
    /// assigned in ascending rank order.
    pub fn new(comm: Comm, region: RegionKind) -> MpixComm {
        let topo = comm.topo().clone();
        let n = comm.nranks();
        let mut dense: FxHashMap<usize, usize> = FxHashMap::default();
        let mut region_of = Vec::with_capacity(n);
        let mut local_rank = Vec::with_capacity(n);
        let mut region_ranks: Vec<Vec<usize>> = Vec::new();
        for r in 0..n {
            let machine_region = topo.region_of(comm.to_world(r), region);
            let next = region_ranks.len();
            let id = *dense.entry(machine_region).or_insert(next);
            if id == region_ranks.len() {
                region_ranks.push(Vec::new());
            }
            region_of.push(id);
            local_rank.push(region_ranks[id].len());
            region_ranks[id].push(r);
        }
        MpixComm {
            comm,
            region_kind: region,
            region_of,
            local_rank,
            region_ranks,
            cached_window: RefCell::new(None),
        }
    }

    pub fn region_kind(&self) -> RegionKind {
        self.region_kind
    }

    pub fn nregions(&self) -> usize {
        self.region_ranks.len()
    }

    /// Region id of `rank`.
    pub fn region(&self, rank: usize) -> usize {
        self.region_of[rank]
    }

    /// This rank's region id.
    pub fn my_region(&self) -> usize {
        self.region_of[self.comm.rank()]
    }

    /// Local rank of `rank` within its region.
    pub fn local_rank(&self, rank: usize) -> usize {
        self.local_rank[rank]
    }

    /// Ranks of region `r`, ascending.
    pub fn region_ranks(&self, r: usize) -> &[usize] {
        &self.region_ranks[r]
    }

    /// Number of ranks in the region containing `rank`.
    pub fn region_size_of(&self, rank: usize) -> usize {
        self.region_ranks[self.region_of[rank]].len()
    }

    /// The paper's corresponding-process rule: when this rank sends the
    /// aggregated buffer for `region`, it targets the rank there with the
    /// same local rank (mod region size for uneven regions).
    pub fn corresponding_rank(&self, region: usize) -> usize {
        let lr = self.local_rank[self.comm.rank()];
        let ranks = &self.region_ranks[region];
        ranks[lr % ranks.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    #[test]
    fn region_maps_node() {
        let w = World::new(
            Topology::quartz(2, 4),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = w.run(|c| async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            (
                mx.my_region(),
                mx.local_rank(c.rank()),
                mx.corresponding_rank(1 - mx.my_region()),
            )
        });
        assert_eq!(out.results[0], (0, 0, 4));
        assert_eq!(out.results[5], (1, 1, 1));
        assert_eq!(out.results[7], (1, 3, 3));
    }

    #[test]
    fn region_maps_socket() {
        let w = World::new(
            Topology::quartz(1, 8),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = w.run(|c| async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Socket);
            (mx.nregions(), mx.my_region(), mx.region_size_of(c.rank()))
        });
        assert_eq!(out.results[0], (2, 0, 4));
        assert_eq!(out.results[4], (2, 1, 4));
    }

    #[test]
    fn region_maps_on_split_comm() {
        // Odd world ranks of a 2x4 world form a sub-communicator: its
        // comm-local ranks 0..4 are world ranks 1,3,5,7 — two per node —
        // and region ids re-index densely from the members.
        let w = World::new(
            Topology::quartz(2, 4),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        let out = w.run(|c| async move {
            let sub = c.split((c.rank() % 2) as u64, c.rank() as i64).await;
            if c.rank() % 2 == 1 {
                let mx = MpixComm::new(sub.clone(), RegionKind::Node);
                Some((
                    mx.nregions(),
                    mx.my_region(),
                    mx.local_rank(sub.rank()),
                    mx.region_ranks(0).to_vec(),
                ))
            } else {
                None
            }
        });
        assert_eq!(out.results[1], Some((2, 0, 0, vec![0, 1])));
        assert_eq!(out.results[7], Some((2, 1, 1, vec![0, 1])));
    }

    #[test]
    fn info_default_is_dispatch() {
        let i = MpixInfo::default();
        assert_eq!(i.algorithm, SddeAlgorithm::Dispatch);
        assert_eq!(i.region, RegionKind::Node);
    }
}
