//! Persistent locality-aware **neighborhood collectives** — the layer that
//! *uses* SDDE-formed patterns (DESIGN.md, layer `mpix::neighbor`).
//!
//! The SDDE APIs ([`crate::mpix::alltoallv_crs`] & friends) exist to *form*
//! a sparse communication pattern; the payoff comes when that pattern is
//! reused every iteration afterwards. This module is the MPI Advance-style
//! consumer side:
//!
//! * [`NeighborComm`] — a distributed-graph topology communicator (the
//!   `MPI_Dist_graph_create_adjacent` analog), built directly from a
//!   [`crate::sparse::CommPkg`], a [`crate::mpix::CrsvResult`] or a
//!   [`crate::mpix::CrsResult`].
//! * [`NeighborAlltoallv`] — a persistent neighbor alltoallv (`init` once,
//!   `start`/`wait` many): pre-sized buffers, fixed tags, and two exchange
//!   strategies — [`NeighborMethod::Standard`] p2p and
//!   [`NeighborMethod::Locality`], which aggregates per region pair like
//!   the formation-side Algorithms 4 & 5 but with a *headerless* wire
//!   format negotiated once at `init`.
//!
//! [`crate::solver::DistMatrix::init_halo`] plugs this into the
//! distributed SpMV, replacing the per-iteration tag-allocating p2p halo
//! exchange for Jacobi/CG.

mod comm;
mod locality;
mod persistent;

pub use comm::NeighborComm;
pub use persistent::{NeighborAlltoallv, NeighborExchange, NeighborMethod};
pub(crate) use persistent::TAG_NEIGHBOR;
