//! Locality-aware routing plan for the persistent neighbor alltoallv —
//! `algos::locality` applied to the *steady-state* exchange.
//!
//! The pattern-formation locality algorithms ship self-describing records
//! (`[dest, origin, count, vals…]`) because the pattern is unknown. A
//! persistent channel has no such excuse: the pattern is frozen at `init`,
//! so the plan below is negotiated **once** — via two small SDDEs, the
//! library dogfooding its own API — and every subsequent exchange ships
//! *headerless* value buffers:
//!
//! * same-region destinations are sent directly (intra-region links are
//!   cheap and contention-free);
//! * all segments bound for region `r` are concatenated (ascending final
//!   destination) into one buffer sent to the **corresponding rank** of
//!   `r` — one inter-region message per (rank, region) pair per iteration;
//! * the corresponding rank splits incoming buffers by final destination
//!   and forwards one combined intra-region message per local consumer.
//!
//! Every offset/length on the receive side is known a priori, so the
//! per-iteration exchange needs no probes, no allreduce, no barrier and no
//! per-iteration tags.

use std::collections::BTreeMap;

use super::comm::NeighborComm;
use crate::mpix::{alltoallv_crs, CrsvArgs, MpixComm, MpixInfo, SddeAlgorithm};

/// One aggregated inter-region send: the sendbuf segments (indices into
/// `NeighborComm::dests`, ascending) concatenated and shipped to the
/// corresponding rank of the destination region.
#[derive(Clone, Debug)]
pub(crate) struct AggSend {
    pub corr: usize,
    pub seg_idx: Vec<usize>,
    pub words: usize,
}

/// One expected incoming aggregated buffer (this rank acting as the
/// corresponding rank of its region for `src`).
#[derive(Clone, Debug)]
pub(crate) struct InterIn {
    pub src: usize,
    pub words: usize,
}

/// A slice of an incoming aggregated buffer: `count` words at `offset`
/// within buffer `in_idx`, originated by rank `origin`.
#[derive(Clone, Debug)]
pub(crate) struct Pull {
    pub in_idx: usize,
    pub offset: usize,
    pub origin: usize,
    pub count: usize,
}

/// One combined intra-region forward: pulls (ascending origin) from the
/// incoming aggregated buffers, concatenated and sent to local rank `dst`.
#[derive(Clone, Debug)]
pub(crate) struct FwdOut {
    pub dst: usize,
    pub pulls: Vec<Pull>,
    pub words: usize,
}

/// One expected intra-region forward from corresponding rank `src`:
/// `(origin, count)` segments in wire order.
#[derive(Clone, Debug)]
pub(crate) struct FwdIn {
    pub src: usize,
    pub segs: Vec<(usize, usize)>,
    pub words: usize,
}

/// The complete frozen routing plan of one rank. The standard (pure p2p)
/// method is the degenerate plan where everything is direct.
#[derive(Clone, Debug, Default)]
pub(crate) struct Plan {
    /// Indices into `dests` sent directly (same region, or all of them for
    /// the standard method).
    pub direct_send_idx: Vec<usize>,
    /// Indices into `sources` received directly.
    pub direct_src_idx: Vec<usize>,
    pub agg_sends: Vec<AggSend>,
    pub inter_in: Vec<InterIn>,
    /// Segments of incoming aggregated buffers consumed by this rank itself.
    pub self_pulls: Vec<Pull>,
    pub fwd_out: Vec<FwdOut>,
    pub fwd_in: Vec<FwdIn>,
}

impl Plan {
    /// Standard method: every channel is a direct p2p message.
    pub fn standard(nc: &NeighborComm) -> Plan {
        Plan {
            direct_send_idx: (0..nc.dests().len()).collect(),
            direct_src_idx: (0..nc.sources().len()).collect(),
            ..Plan::default()
        }
    }
}

/// Negotiate the locality-aware plan. **Collective** over the world: the
/// two setup SDDEs below contain allreduces. Cost is paid once per `init`
/// and amortized over every subsequent exchange. `mx` is the caller's
/// extension communicator (same region granularity, asserted by `init`),
/// reused so its cached region tables are not rebuilt here.
pub(crate) async fn build_locality_plan(mx: &MpixComm, nc: &NeighborComm) -> Plan {
    let c = nc.comm();
    let kind = nc.region_kind();
    let topo = c.topo().clone();
    let me = c.rank();
    let my_region = topo.region_of(me, kind);
    let dests = nc.dests();
    let sources = nc.sources();

    // -- send side: split direct vs per-region aggregated. ----------------
    let mut direct_send_idx = Vec::new();
    let mut by_region: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, &(d, _)) in dests.iter().enumerate() {
        if topo.region_of(d, kind) == my_region {
            direct_send_idx.push(i);
        } else {
            by_region.entry(topo.region_of(d, kind)).or_default().push(i);
        }
    }
    let agg_sends: Vec<AggSend> = by_region
        .into_iter()
        .map(|(r, seg_idx)| AggSend {
            corr: topo.corresponding_rank(me, r, kind),
            words: seg_idx.iter().map(|&i| dests[i].1).sum(),
            seg_idx,
        })
        .collect();

    // -- setup SDDE 1: describe each aggregated buffer's layout to its
    //    corresponding rank as (final_dest, count) pairs, ascending dest
    //    (the wire order of the headerless per-iteration buffer). ---------
    let info = MpixInfo::with_algorithm(SddeAlgorithm::Personalized);
    let args1 = CrsvArgs {
        dest: agg_sends.iter().map(|a| a.corr).collect(),
        sendcounts: agg_sends.iter().map(|a| a.seg_idx.len() * 2).collect(),
        sendvals: agg_sends
            .iter()
            .flat_map(|a| {
                a.seg_idx
                    .iter()
                    .flat_map(|&i| [dests[i].0 as u64, dests[i].1 as u64])
            })
            .collect(),
    };
    let res1 = alltoallv_crs(mx, &info, &args1)
        .await
        .expect("neighbor setup SDDE (inter-region plans)");

    // -- intermediary role: record incoming layouts, derive forwards. -----
    // res1 is canonical (ascending src), so per-destination pulls come out
    // ascending by origin — the wire order final consumers will assume.
    let mut inter_in = Vec::new();
    let mut self_pulls = Vec::new();
    let mut fwd_map: BTreeMap<usize, Vec<Pull>> = BTreeMap::new();
    for i in 0..res1.recv_nnz() {
        let src = res1.src[i];
        let in_idx = inter_in.len();
        let mut offset = 0usize;
        for ch in res1.vals(i).chunks(2) {
            let (d, count) = (ch[0] as usize, ch[1] as usize);
            let pull = Pull {
                in_idx,
                offset,
                origin: src,
                count,
            };
            if d == me {
                self_pulls.push(pull);
            } else {
                debug_assert_eq!(topo.region_of(d, kind), my_region, "misrouted segment");
                fwd_map.entry(d).or_default().push(pull);
            }
            offset += count;
        }
        inter_in.push(InterIn { src, words: offset });
    }
    let fwd_out: Vec<FwdOut> = fwd_map
        .into_iter()
        .map(|(dst, pulls)| FwdOut {
            dst,
            words: pulls.iter().map(|p| p.count).sum(),
            pulls,
        })
        .collect();

    // -- setup SDDE 2: describe each forward's layout to its consumer as
    //    (origin, count) pairs in wire order. ----------------------------
    let args2 = CrsvArgs {
        dest: fwd_out.iter().map(|f| f.dst).collect(),
        sendcounts: fwd_out.iter().map(|f| f.pulls.len() * 2).collect(),
        sendvals: fwd_out
            .iter()
            .flat_map(|f| {
                f.pulls
                    .iter()
                    .flat_map(|p| [p.origin as u64, p.count as u64])
            })
            .collect(),
    };
    let res2 = alltoallv_crs(mx, &info, &args2)
        .await
        .expect("neighbor setup SDDE (intra-region plans)");
    let fwd_in: Vec<FwdIn> = (0..res2.recv_nnz())
        .map(|i| {
            let segs: Vec<(usize, usize)> = res2
                .vals(i)
                .chunks(2)
                .map(|ch| (ch[0] as usize, ch[1] as usize))
                .collect();
            FwdIn {
                src: res2.src[i],
                words: segs.iter().map(|&(_, c)| c).sum(),
                segs,
            }
        })
        .collect();

    // -- receive side: same-region sources arrive directly. ---------------
    let direct_src_idx: Vec<usize> = sources
        .iter()
        .enumerate()
        .filter(|&(_, &(s, _))| topo.region_of(s, kind) == my_region)
        .map(|(i, _)| i)
        .collect();

    let plan = Plan {
        direct_send_idx,
        direct_src_idx,
        agg_sends,
        inter_in,
        self_pulls,
        fwd_out,
        fwd_in,
    };

    // Every source must be covered by exactly one route with the exact
    // per-exchange word count.
    #[cfg(debug_assertions)]
    {
        let mut route: BTreeMap<usize, usize> = BTreeMap::new();
        for &i in &plan.direct_src_idx {
            *route.entry(sources[i].0).or_default() += sources[i].1;
        }
        for p in &plan.self_pulls {
            *route.entry(p.origin).or_default() += p.count;
        }
        for f in &plan.fwd_in {
            for &(origin, count) in &f.segs {
                *route.entry(origin).or_default() += count;
            }
        }
        let expect: BTreeMap<usize, usize> = sources.iter().copied().collect();
        debug_assert_eq!(route, expect, "rank {me}: plan does not cover sources");
    }

    plan
}
