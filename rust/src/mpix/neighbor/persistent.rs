//! Persistent neighbor alltoallv: `init` once → `start`/`wait` many.
//!
//! The MPI analog is `MPIX_Neighbor_alltoallv_init` + `MPI_Start` /
//! `MPI_Wait` on the persistent request. Everything amortizable is done in
//! [`NeighborAlltoallv::init`]: tag allocation (one pair per request object,
//! **never** per iteration), buffer sizing, displacement tables and — for
//! the locality-aware method — the full aggregation/forwarding plan.
//!
//! Fixed tags are safe across arbitrarily many exchanges because the
//! simulated MPI (like real MPI) guarantees non-overtaking per (src, dst)
//! pair and matches posted receives in post order: iteration `k`'s
//! message from a given source always pairs with iteration `k`'s receive.
//! Overlapping exchanges (`start` A, `start` B, `wait` A, `wait` B) are
//! supported; with the locality-aware method they must be waited in start
//! order, since forwarding work happens in `wait`: waiting exchange B
//! first would emit B's intra-region forwards, which then match the
//! forward receives that exchange A posted — silent data corruption. The
//! request object tracks start/wait sequence numbers and **panics** on an
//! out-of-order locality-aware wait instead (the standard method has no
//! such constraint — its matching is purely posted-order).

use std::cell::Cell;

use crate::mpi::{waitall, Payload, Request, Tag};
use crate::mpix::MpixComm;

use super::comm::NeighborComm;
use super::locality::{build_locality_plan, Plan};

/// User-tag family for persistent neighbor exchanges — disjoint from the
/// SDDE family (`0x1000..0x3000`) and the legacy halo family
/// (`0x0010_0000..0x0100_0000`). Two tags (data, forward) per `init`.
pub(crate) const TAG_NEIGHBOR: Tag = 0x4000;

/// Steady-state exchange strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeighborMethod {
    /// One p2p message per neighbor per iteration.
    Standard,
    /// Aggregate per destination region; one message per region pair over
    /// the inter-region tier, redistributed intra-region (Collom et al.,
    /// arXiv 2306.01876, applied to the persistent exchange).
    Locality,
}

impl NeighborMethod {
    pub fn name(&self) -> &'static str {
        match self {
            NeighborMethod::Standard => "standard",
            NeighborMethod::Locality => "locality",
        }
    }

    /// No "p2p" alias here: everywhere else in the crate "p2p" names the
    /// legacy *non-persistent* halo path, not the persistent standard
    /// engine.
    pub fn parse(s: &str) -> Option<NeighborMethod> {
        match s.to_ascii_lowercase().as_str() {
            "standard" | "std" => Some(NeighborMethod::Standard),
            "locality" | "loc" => Some(NeighborMethod::Locality),
            _ => None,
        }
    }
}

/// An in-flight exchange: the posted requests plus the receive buffer
/// being assembled. Produced by [`NeighborAlltoallv::start`], consumed by
/// [`NeighborAlltoallv::wait`].
pub struct NeighborExchange {
    send_reqs: Vec<Request>,
    direct_recv: Vec<Request>,
    inter_recv: Vec<Request>,
    fwd_recv: Vec<Request>,
    recvbuf: Vec<f64>,
    /// Start-order sequence number (checked by locality-aware `wait`).
    seq: u64,
}

/// The persistent request object. `sendbuf`/`recvbuf` are flat `f64`
/// vectors laid out per the [`NeighborComm`] adjacency lists (ascending
/// neighbor rank; displacements are prefix sums of the per-neighbor
/// counts — exactly `MPI_Neighbor_alltoallv`'s `sdispls`/`rdispls`).
pub struct NeighborAlltoallv {
    nc: NeighborComm,
    method: NeighborMethod,
    plan: Plan,
    tag_data: Tag,
    tag_fwd: Tag,
    sdispls: Vec<usize>,
    rdispls: Vec<usize>,
    send_words: usize,
    recv_words: usize,
    /// Exchanges started / waited so far (wait-order hazard detection).
    started: Cell<u64>,
    waited: Cell<u64>,
}

impl NeighborAlltoallv {
    /// Set up the persistent exchange. Must be called **collectively** (in
    /// the same order on every rank): tag sequence numbers must agree, and
    /// the locality-aware plan negotiation contains allreduces. `mx` must
    /// be at the same region granularity as the [`NeighborComm`].
    pub async fn init(
        mx: &MpixComm,
        nc: &NeighborComm,
        method: NeighborMethod,
    ) -> NeighborAlltoallv {
        assert_eq!(
            mx.region_kind(),
            nc.region_kind(),
            "MpixComm/NeighborComm region granularity mismatch"
        );
        let c = nc.comm();
        let seq = c.next_seq(TAG_NEIGHBOR);
        let base = TAG_NEIGHBOR + (seq % 0x2000) * 2;
        let plan = match method {
            NeighborMethod::Standard => Plan::standard(nc),
            NeighborMethod::Locality => build_locality_plan(mx, nc).await,
        };
        let mut sdispls = Vec::with_capacity(nc.dests().len());
        let mut send_words = 0usize;
        for &(_, cnt) in nc.dests() {
            sdispls.push(send_words);
            send_words += cnt;
        }
        let mut rdispls = Vec::with_capacity(nc.sources().len());
        let mut recv_words = 0usize;
        for &(_, cnt) in nc.sources() {
            rdispls.push(recv_words);
            recv_words += cnt;
        }
        NeighborAlltoallv {
            nc: nc.clone(),
            method,
            plan,
            tag_data: base,
            tag_fwd: base + 1,
            sdispls,
            rdispls,
            send_words,
            recv_words,
            started: Cell::new(0),
            waited: Cell::new(0),
        }
    }

    pub fn method(&self) -> NeighborMethod {
        self.method
    }

    pub fn neighbor_comm(&self) -> &NeighborComm {
        &self.nc
    }

    /// Send displacements (prefix sums of the dest counts).
    pub fn sdispls(&self) -> &[usize] {
        &self.sdispls
    }

    /// Receive displacements (prefix sums of the source counts).
    pub fn rdispls(&self) -> &[usize] {
        &self.rdispls
    }

    pub fn send_words(&self) -> usize {
        self.send_words
    }

    pub fn recv_words(&self) -> usize {
        self.recv_words
    }

    /// Receive-buffer slot (displacement, count) of rank `origin`, if it
    /// is a source.
    fn src_slot(&self, origin: usize) -> (usize, usize) {
        let i = self
            .nc
            .sources()
            .binary_search_by_key(&origin, |&(s, _)| s)
            .unwrap_or_else(|_| panic!("{origin} is not a source of rank {}", self.nc.comm().rank()));
        (self.rdispls[i], self.nc.sources()[i].1)
    }

    /// MPI_Start analog: pre-post every receive this exchange consumes,
    /// then inject the direct and aggregated sends.
    pub async fn start(&self, sendbuf: &[f64]) -> NeighborExchange {
        let c = self.nc.comm();
        assert_eq!(sendbuf.len(), self.send_words, "sendbuf length mismatch");

        let mut direct_recv = Vec::with_capacity(self.plan.direct_src_idx.len());
        for &i in &self.plan.direct_src_idx {
            direct_recv.push(c.irecv(self.nc.sources()[i].0, self.tag_data).await);
        }
        let mut inter_recv = Vec::with_capacity(self.plan.inter_in.len());
        for ii in &self.plan.inter_in {
            inter_recv.push(c.irecv(ii.src, self.tag_data).await);
        }
        let mut fwd_recv = Vec::with_capacity(self.plan.fwd_in.len());
        for fi in &self.plan.fwd_in {
            fwd_recv.push(c.irecv(fi.src, self.tag_fwd).await);
        }

        let mut send_reqs = Vec::with_capacity(
            self.plan.direct_send_idx.len() + self.plan.agg_sends.len(),
        );
        for &i in &self.plan.direct_send_idx {
            let (d, cnt) = self.nc.dests()[i];
            let s = self.sdispls[i];
            send_reqs.push(
                c.isend(d, self.tag_data, Payload::doubles(&sendbuf[s..s + cnt]))
                    .await,
            );
        }
        for a in &self.plan.agg_sends {
            let mut buf = Vec::with_capacity(a.words);
            for &i in &a.seg_idx {
                let (_, cnt) = self.nc.dests()[i];
                let s = self.sdispls[i];
                buf.extend_from_slice(&sendbuf[s..s + cnt]);
            }
            // Packing cost, matching the formation-side locality algorithms
            // (~0.25 ns/word streaming copy).
            c.charge_cpu(a.words as u64 / 4).await;
            send_reqs.push(c.isend(a.corr, self.tag_data, Payload::doubles(&buf)).await);
        }

        let seq = self.started.get();
        self.started.set(seq + 1);
        NeighborExchange {
            send_reqs,
            direct_recv,
            inter_recv,
            fwd_recv,
            recvbuf: vec![0.0; self.recv_words],
            seq,
        }
    }

    /// MPI_Wait analog: complete the exchange and return the assembled
    /// receive buffer (layout per [`Self::rdispls`]).
    pub async fn wait(&self, mut ex: NeighborExchange) -> Vec<f64> {
        let c = self.nc.comm();

        // Locality-aware forwarding happens *inside* wait: waiting a newer
        // exchange first would push its tag_fwd messages into an older
        // exchange's posted forward receives (silent corruption) — refuse.
        if self.method == NeighborMethod::Locality {
            assert_eq!(
                ex.seq,
                self.waited.get(),
                "locality-aware NeighborAlltoallv waited out of start order \
                 (exchange #{} waited while #{} is the oldest outstanding); \
                 wait in start order or use NeighborMethod::Standard",
                ex.seq,
                self.waited.get(),
            );
        }
        self.waited.set(ex.seq + 1);

        // 1. Corresponding-rank role: drain the aggregated inter-region
        //    buffers, keep own segments, forward the rest intra-region.
        let inter_recv = std::mem::take(&mut ex.inter_recv);
        let mut bufs: Vec<Vec<f64>> = Vec::with_capacity(inter_recv.len());
        for (k, req) in inter_recv.into_iter().enumerate() {
            let m = req.await.expect("aggregated neighbor recv");
            let vals = m.payload.as_doubles();
            assert_eq!(
                vals.len(),
                self.plan.inter_in[k].words,
                "aggregated buffer size mismatch from {}",
                self.plan.inter_in[k].src
            );
            bufs.push(vals);
        }
        for p in &self.plan.self_pulls {
            let (displ, cnt) = self.src_slot(p.origin);
            debug_assert_eq!(cnt, p.count);
            ex.recvbuf[displ..displ + p.count]
                .copy_from_slice(&bufs[p.in_idx][p.offset..p.offset + p.count]);
        }
        for f in &self.plan.fwd_out {
            let mut buf = Vec::with_capacity(f.words);
            for p in &f.pulls {
                buf.extend_from_slice(&bufs[p.in_idx][p.offset..p.offset + p.count]);
            }
            c.charge_cpu(f.words as u64 / 4).await;
            ex.send_reqs
                .push(c.isend(f.dst, self.tag_fwd, Payload::doubles(&buf)).await);
        }

        // 2. Direct channels.
        let direct_recv = std::mem::take(&mut ex.direct_recv);
        for (k, req) in direct_recv.into_iter().enumerate() {
            let i = self.plan.direct_src_idx[k];
            let (src, cnt) = self.nc.sources()[i];
            let m = req.await.expect("direct neighbor recv");
            let vals = m.payload.as_doubles();
            assert_eq!(vals.len(), cnt, "direct message size mismatch from {src}");
            ex.recvbuf[self.rdispls[i]..self.rdispls[i] + cnt].copy_from_slice(&vals);
        }

        // 3. Intra-region forwards.
        let fwd_recv = std::mem::take(&mut ex.fwd_recv);
        for (k, req) in fwd_recv.into_iter().enumerate() {
            let fi = &self.plan.fwd_in[k];
            let m = req.await.expect("forwarded neighbor recv");
            let vals = m.payload.as_doubles();
            assert_eq!(vals.len(), fi.words, "forward size mismatch from {}", fi.src);
            let mut off = 0usize;
            for &(origin, count) in &fi.segs {
                let (displ, cnt) = self.src_slot(origin);
                debug_assert_eq!(cnt, count);
                ex.recvbuf[displ..displ + count].copy_from_slice(&vals[off..off + count]);
                off += count;
            }
        }

        waitall(&ex.send_reqs).await;
        ex.recvbuf
    }

    /// One full exchange (`start` + `wait`).
    pub async fn exchange(&self, sendbuf: &[f64]) -> Vec<f64> {
        let ex = self.start(sendbuf).await;
        self.wait(ex).await
    }
}
