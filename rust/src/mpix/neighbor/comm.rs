//! `NeighborComm` — the distributed-graph topology communicator
//! (`MPI_Dist_graph_create_adjacent` analog).
//!
//! A [`NeighborComm`] freezes the *steady-state* communication graph of one
//! rank: which ranks it sends to every iteration (and how many words each),
//! and which ranks it receives from (and how many words each). It is built
//! directly from what an SDDE discovered — a [`CommPkg`], a
//! [`CrsvResult`], or a [`CrsResult`] — so the pattern the SDDE *formed* is
//! handed straight to the collectives that *use* it.

use crate::mpi::Comm;
use crate::mpix::{CrsArgs, CrsResult, CrsvArgs, CrsvResult, MpixComm};
use crate::simnet::RegionKind;
use crate::sparse::CommPkg;

/// Per-rank view of a fixed sparse communication graph: sorted
/// `(neighbor, words-per-exchange)` lists for both directions, plus the
/// region granularity the locality-aware exchange aggregates over.
/// (No `Debug` derive: the embedded [`Comm`] handle has none.)
#[derive(Clone)]
pub struct NeighborComm {
    comm: Comm,
    region_kind: RegionKind,
    /// (source rank, words received from it per exchange), ascending.
    sources: Vec<(usize, usize)>,
    /// (destination rank, words sent to it per exchange), ascending.
    dests: Vec<(usize, usize)>,
}

impl NeighborComm {
    /// The `MPI_Dist_graph_create_adjacent` analog: both adjacency lists
    /// are supplied explicitly. Lists are sorted; duplicate neighbors,
    /// self edges, out-of-range ranks and zero-length channels are
    /// programming errors (omit the neighbor instead of a zero count).
    pub fn create_adjacent(
        comm: Comm,
        region: RegionKind,
        mut sources: Vec<(usize, usize)>,
        mut dests: Vec<(usize, usize)>,
    ) -> NeighborComm {
        let me = comm.rank();
        let n = comm.nranks();
        sources.sort_unstable();
        dests.sort_unstable();
        for list in [&sources, &dests] {
            for w in list.windows(2) {
                assert!(w[0].0 < w[1].0, "duplicate neighbor {}", w[1].0);
            }
            for &(r, cnt) in list.iter() {
                assert!(r < n, "neighbor {r} out of range (nranks {n})");
                assert_ne!(r, me, "rank {me} listed itself as a neighbor");
                assert!(cnt > 0, "zero-length channel to {r} (omit the neighbor)");
            }
        }
        NeighborComm {
            comm,
            region_kind: region,
            sources,
            dests,
        }
    }

    /// Build from an SDDE-formed [`CommPkg`]: every later exchange sends
    /// `send_to[i].1.len()` values to each `send_to[i].0` and receives
    /// `recv_from[i].1.len()` values from each `recv_from[i].0` — the SpMV
    /// halo-exchange graph.
    pub fn from_commpkg(mx: &MpixComm, pkg: &CommPkg) -> NeighborComm {
        NeighborComm::create_adjacent(
            mx.comm.clone(),
            mx.region_kind(),
            pkg.recv_from
                .iter()
                .map(|(owner, cols)| (*owner, cols.len()))
                .collect(),
            pkg.send_to
                .iter()
                .map(|(nbr, rows)| (*nbr, rows.len()))
                .collect(),
        )
    }

    /// Build from a raw variable-size SDDE call (`MPIX_Alltoallv_crs`)
    /// used Hypre-style: the SDDE sent *index requests* to the owners
    /// (`args`), and learned who requested indices from this rank (`res`).
    /// The steady-state data flow is therefore the *reverse* of the SDDE:
    /// values go to every `res.src[i]` (`res.recvcounts[i]` words — the
    /// indices it requested) and arrive from every `args.dest[i]`
    /// (`args.sendcounts[i]` words — the indices we requested).
    pub fn from_crsv(mx: &MpixComm, args: &CrsvArgs, res: &CrsvResult) -> NeighborComm {
        NeighborComm::create_adjacent(
            mx.comm.clone(),
            mx.region_kind(),
            args.dest
                .iter()
                .zip(&args.sendcounts)
                .map(|(&d, &c)| (d, c))
                .collect(),
            res.src
                .iter()
                .zip(&res.recvcounts)
                .map(|(&s, &c)| (s, c))
                .collect(),
        )
    }

    /// Build from a constant-size SDDE used CELLAR-style
    /// (`MPIX_Alltoall_crs` with `sendcount == 1`, one future message
    /// *size* per destination): this rank will send `args.sendvals[i]`
    /// words to each `args.dest[i]` and receive `res.recvvals[i]` words
    /// from each `res.src[i]`. Zero-size channels are dropped.
    pub fn from_crs_sizes(mx: &MpixComm, args: &CrsArgs, res: &CrsResult) -> NeighborComm {
        assert_eq!(args.sendcount, 1, "from_crs_sizes expects one size per destination");
        NeighborComm::create_adjacent(
            mx.comm.clone(),
            mx.region_kind(),
            res.src
                .iter()
                .zip(&res.recvvals)
                .map(|(&s, &c)| (s, c as usize))
                .filter(|&(_, c)| c > 0)
                .collect(),
            args.dest
                .iter()
                .zip(&args.sendvals)
                .map(|(&d, &c)| (d, c as usize))
                .filter(|&(_, c)| c > 0)
                .collect(),
        )
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    pub fn region_kind(&self) -> RegionKind {
        self.region_kind
    }

    /// Receive adjacency: (source rank, words per exchange), ascending.
    pub fn sources(&self) -> &[(usize, usize)] {
        &self.sources
    }

    /// Send adjacency: (destination rank, words per exchange), ascending.
    pub fn dests(&self) -> &[(usize, usize)] {
        &self.dests
    }

    /// Total words sent per exchange.
    pub fn send_words(&self) -> usize {
        self.dests.iter().map(|&(_, c)| c).sum()
    }

    /// Total words received per exchange.
    pub fn recv_words(&self) -> usize {
        self.sources.iter().map(|&(_, c)| c).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::simnet::{CostModel, MpiFlavor, Topology};

    fn comm_of(nodes: usize, ppn: usize, rank: usize) -> Comm {
        let w = World::new(
            Topology::quartz(nodes, ppn),
            CostModel::preset(MpiFlavor::Mvapich2),
        );
        w.comm(rank)
    }

    #[test]
    fn create_adjacent_sorts_and_sizes() {
        let nc = NeighborComm::create_adjacent(
            comm_of(2, 2, 0),
            RegionKind::Node,
            vec![(3, 2), (1, 5)],
            vec![(2, 4)],
        );
        assert_eq!(nc.sources(), &[(1, 5), (3, 2)]);
        assert_eq!(nc.dests(), &[(2, 4)]);
        assert_eq!(nc.recv_words(), 7);
        assert_eq!(nc.send_words(), 4);
    }

    #[test]
    #[should_panic(expected = "listed itself")]
    fn create_adjacent_rejects_self() {
        NeighborComm::create_adjacent(
            comm_of(1, 2, 0),
            RegionKind::Node,
            vec![(0, 1)],
            vec![],
        );
    }

    #[test]
    #[should_panic(expected = "zero-length")]
    fn create_adjacent_rejects_zero_count() {
        NeighborComm::create_adjacent(
            comm_of(1, 2, 0),
            RegionKind::Node,
            vec![],
            vec![(1, 0)],
        );
    }
}
