//! Row-distributed sparse matrix with SDDE-formed halo exchange.
//!
//! Two halo-exchange engines share the [`CommPkg`] pattern:
//!
//! * the **persistent** path ([`DistMatrix::init_halo`]): a
//!   [`NeighborAlltoallv`] over a [`NeighborComm`] built from the package —
//!   fixed tags, pre-sized buffers, optional locality-aware aggregation.
//!   This is what Jacobi/CG should run on in the steady state.
//! * the **legacy p2p** path ([`DistMatrix::halo_exchange_p2p`]): one
//!   tagged isend/recv per neighbor per exchange, kept as the reference
//!   implementation for agreement tests.

use std::collections::BTreeMap;

use crate::mpi::{waitall, Comm, Payload, Tag};
use crate::mpix::{MpixComm, NeighborAlltoallv, NeighborComm, NeighborMethod};
use crate::sparse::{CommPkg, CsrMatrix, MatrixPreset, Partition};

/// Tag family for the legacy p2p halo exchange (user tag space, disjoint
/// from the SDDE family `0x1000..0x3000` and the persistent-neighbor
/// family `0x4000..0x8000`).
pub(crate) const TAG_HALO: Tag = 0x0010_0000;
/// Distinct halo tags before the sequence recycles. The old window of
/// 0x400 wrapped after 1024 exchanges, which could cross-talk between
/// overlapping exchanges; ~15.7M leaves no realistic overlap window (and
/// the persistent path needs no per-iteration tags at all).
pub(crate) const TAG_HALO_WINDOW: Tag = 0x00F0_0000;

/// Pluggable local SpMV: `x_ext` is `[x_local ++ ghosts]` (ghost order =
/// `DistMatrix::ghost_cols`); returns `y_local`.
pub trait LocalSpmv {
    fn apply(&self, x_ext: &[f64]) -> Vec<f64>;
}

/// Pure-rust CSR local kernel.
pub struct CsrLocal<'a>(pub &'a CsrMatrix);

impl LocalSpmv for CsrLocal<'_> {
    fn apply(&self, x_ext: &[f64]) -> Vec<f64> {
        self.0.spmv(x_ext)
    }
}

/// The local block of a row-distributed matrix plus its communication
/// package. Columns are remapped: `[0, local_n)` are this rank's rows;
/// `local_n + k` is ghost `k` (global column `ghost_cols[k]`).
pub struct DistMatrix {
    pub part: Partition,
    pub rank: usize,
    /// Local CSR with remapped columns (`ncols = local_n + nghost`).
    pub local: CsrMatrix,
    /// Global column of each ghost slot, ascending.
    pub ghost_cols: Vec<usize>,
    /// SDDE-formed halo-exchange pattern.
    pub pkg: CommPkg,
    /// Persistent neighbor exchange over `pkg` ([`DistMatrix::init_halo`]);
    /// when absent, [`DistMatrix::halo_exchange`] falls back to the legacy
    /// p2p path.
    halo: Option<NeighborAlltoallv>,
    /// Local index of each sent value, flat in `pkg.send_to` order — the
    /// halo pack is a pure gather.
    halo_gather: Vec<usize>,
    /// `x_ext` slot of each received value, flat in `pkg.recv_from` order —
    /// the ghost scatter is a pure indexed copy (no per-word search).
    halo_scatter: Vec<usize>,
}

impl DistMatrix {
    /// Assemble this rank's block from the row-deterministic generator and
    /// an SDDE-formed communication package.
    pub fn build(
        preset: &MatrixPreset,
        part: Partition,
        rank: usize,
        seed: u64,
        pkg: CommPkg,
    ) -> DistMatrix {
        let (start, end) = part.range(rank);
        let local_n = end - start;

        // Ghost map: all off-process columns, ascending.
        let ghost_cols: Vec<usize> = pkg
            .recv_from
            .iter()
            .flat_map(|(_, cols)| cols.iter().copied())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        let ghost_idx: BTreeMap<usize, usize> = ghost_cols
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, local_n + i))
            .collect();

        let rows: Vec<Vec<(usize, f64)>> = (start..end)
            .map(|row| {
                preset
                    .row_entries(row, seed)
                    .into_iter()
                    .map(|(c, v)| {
                        let lc = if (start..end).contains(&c) {
                            c - start
                        } else {
                            *ghost_idx
                                .get(&c)
                                .unwrap_or_else(|| panic!("column {c} missing from comm pkg"))
                        };
                        (lc, v)
                    })
                    .collect()
            })
            .collect();
        let local = CsrMatrix::from_rows(local_n, local_n + ghost_cols.len(), rows);
        let halo_gather: Vec<usize> = pkg
            .send_to
            .iter()
            .flat_map(|(_, rws)| rws.iter().map(|&r| r - start))
            .collect();
        let halo_scatter: Vec<usize> = pkg
            .recv_from
            .iter()
            .flat_map(|(_, cols)| cols.iter().map(|c| ghost_idx[c]))
            .collect();
        DistMatrix {
            part,
            rank,
            local,
            ghost_cols,
            pkg,
            halo: None,
            halo_gather,
            halo_scatter,
        }
    }

    /// Switch the halo exchange to a persistent neighborhood collective
    /// over this matrix's [`CommPkg`]. Collective: every rank must call it
    /// with the same `method` (the locality plan negotiation runs SDDEs).
    pub async fn init_halo(&mut self, mx: &MpixComm, method: NeighborMethod) {
        let nc = NeighborComm::from_commpkg(mx, &self.pkg);
        self.init_halo_over(mx, &nc, method).await;
    }

    /// As [`DistMatrix::init_halo`], but over an already-built
    /// [`NeighborComm`] — e.g. the one
    /// [`crate::sparse::form_neighborhood`] returned next to the package.
    pub async fn init_halo_over(
        &mut self,
        mx: &MpixComm,
        nc: &NeighborComm,
        method: NeighborMethod,
    ) {
        assert_eq!(mx.comm.rank(), self.rank, "init_halo on the wrong rank");
        debug_assert_eq!(
            nc.sources().len(),
            self.pkg.recv_from.len(),
            "NeighborComm does not match this matrix's CommPkg"
        );
        debug_assert_eq!(nc.dests().len(), self.pkg.send_to.len());
        self.halo = Some(NeighborAlltoallv::init(mx, nc, method).await);
    }

    /// The active persistent exchange, if [`DistMatrix::init_halo`] ran.
    pub fn persistent_halo(&self) -> Option<&NeighborAlltoallv> {
        self.halo.as_ref()
    }

    pub fn local_n(&self) -> usize {
        self.local.nrows
    }

    pub fn nghost(&self) -> usize {
        self.ghost_cols.len()
    }

    /// Halo exchange: send owned entries of `x` per the package, receive
    /// ghost values; returns the extended vector `[x ++ ghosts]`. Runs on
    /// the persistent neighborhood collective when one was initialized
    /// ([`DistMatrix::init_halo`]), else on the legacy p2p path.
    pub async fn halo_exchange(&self, comm: &Comm, x: &[f64]) -> Vec<f64> {
        match &self.halo {
            Some(p) => self.halo_exchange_persistent(p, x).await,
            None => self.halo_exchange_p2p(comm, x).await,
        }
    }

    /// `[x ++ zeroed ghosts]`, ready for ghost scatter.
    fn x_ext_base(&self, x: &[f64]) -> Vec<f64> {
        let mut x_ext = Vec::with_capacity(self.local_n() + self.nghost());
        x_ext.extend_from_slice(x);
        x_ext.resize(self.local_n() + self.nghost(), 0.0);
        x_ext
    }

    /// Persistent path: the pack is a pure gather and the ghost scatter a
    /// pure indexed copy — all mapping was precomputed at build time.
    async fn halo_exchange_persistent(&self, p: &NeighborAlltoallv, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.local_n());
        let sendbuf: Vec<f64> = self.halo_gather.iter().map(|&i| x[i]).collect();
        let recvbuf = p.exchange(&sendbuf).await;
        debug_assert_eq!(recvbuf.len(), self.halo_scatter.len());
        let mut x_ext = self.x_ext_base(x);
        for (k, &slot) in self.halo_scatter.iter().enumerate() {
            x_ext[slot] = recvbuf[k];
        }
        x_ext
    }

    /// Legacy p2p reference path: one tagged message per neighbor per
    /// exchange (fresh tag per exchange, recycled after
    /// [`TAG_HALO_WINDOW`] exchanges).
    pub async fn halo_exchange_p2p(&self, comm: &Comm, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.local_n());
        let tag = TAG_HALO + comm.next_seq(TAG_HALO) % TAG_HALO_WINDOW;

        let mut reqs = Vec::with_capacity(self.pkg.send_to.len());
        let mut soff = 0usize;
        for (nbr, rows) in &self.pkg.send_to {
            let vals: Vec<f64> = self.halo_gather[soff..soff + rows.len()]
                .iter()
                .map(|&i| x[i])
                .collect();
            soff += rows.len();
            reqs.push(comm.isend(*nbr, tag, Payload::doubles(&vals)).await);
        }

        let mut x_ext = self.x_ext_base(x);
        let mut roff = 0usize;
        for (owner, cols) in &self.pkg.recv_from {
            let m = comm.recv(*owner, tag).await;
            let vals = m.payload.as_doubles();
            assert_eq!(vals.len(), cols.len(), "halo size mismatch from {owner}");
            for (k, v) in vals.into_iter().enumerate() {
                x_ext[self.halo_scatter[roff + k]] = v;
            }
            roff += cols.len();
        }
        waitall(&reqs).await;
        x_ext
    }

    /// Distributed SpMV with a pluggable local kernel.
    pub async fn spmv_with(&self, comm: &Comm, x: &[f64], kernel: &impl LocalSpmv) -> Vec<f64> {
        let x_ext = self.halo_exchange(comm, x).await;
        kernel.apply(&x_ext)
    }

    /// Distributed SpMV with the built-in rust CSR kernel.
    pub async fn spmv(&self, comm: &Comm, x: &[f64]) -> Vec<f64> {
        self.spmv_with(comm, x, &CsrLocal(&self.local)).await
    }

    /// Diagonal of the local block (global diag entries for this rank's
    /// rows) — used by Jacobi.
    pub fn local_diag(&self) -> Vec<f64> {
        (0..self.local_n())
            .map(|r| {
                self.local
                    .row_cols(r)
                    .iter()
                    .zip(self.local.row_vals(r))
                    .find(|(&c, _)| c == r)
                    .map(|(_, &v)| v)
                    .unwrap_or(0.0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::mpix::{MpixComm, MpixInfo, SddeAlgorithm};
    use crate::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
    use crate::sparse::{form_commpkg, SpmvPattern};
    use std::rc::Rc;

    /// Distributed SpMV must equal the sequential SpMV, for every SDDE
    /// algorithm forming the pattern.
    #[test]
    fn distributed_spmv_matches_sequential() {
        let preset = MatrixPreset::poisson2d(16, 12);
        let topo = Topology::quartz(2, 4);
        let nranks = topo.nranks();
        let part = Partition::new(preset.n, nranks);
        let a_seq = preset.to_csr(3);
        let x_glob: Vec<f64> = (0..preset.n).map(|i| (i % 13) as f64 - 6.0).collect();
        let y_expect = a_seq.spmv(&x_glob);

        for algo in SddeAlgorithm::VARIABLE {
            let preset = preset.clone();
            let x_glob = x_glob.clone();
            let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
            let preset2 = Rc::new(preset);
            let xg = Rc::new(x_glob);
            let out = world.run(move |c| {
                let preset = preset2.clone();
                let xg = xg.clone();
                async move {
                    let rank = c.rank();
                    let mx = MpixComm::new(c.clone(), RegionKind::Node);
                    let info = MpixInfo::with_algorithm(algo);
                    let pat = SpmvPattern::build(&preset, part, rank, 3);
                    let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                    let a = DistMatrix::build(&preset, part, rank, 3, pkg);
                    let (s, e) = part.range(rank);
                    a.spmv(&c, &xg[s..e]).await
                }
            });
            let got: Vec<f64> = out.results.into_iter().flatten().collect();
            assert_eq!(got.len(), y_expect.len());
            for (i, (g, e)) in got.iter().zip(&y_expect).enumerate() {
                assert!((g - e).abs() < 1e-12, "algo {algo:?} row {i}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn repeated_spmv_uses_fresh_tags() {
        // Two SpMVs in a row must not steal each other's halo messages.
        let preset = MatrixPreset::poisson2d(8, 8);
        let topo = Topology::quartz(1, 4);
        let part = Partition::new(preset.n, topo.nranks());
        let a_seq = preset.to_csr(0);
        let x1: Vec<f64> = (0..preset.n).map(|i| i as f64).collect();
        let y1 = a_seq.spmv(&x1);
        let y2 = a_seq.spmv(&y1);
        let world = World::new(topo, CostModel::preset(MpiFlavor::OpenMpi));
        let x1rc = Rc::new(x1);
        let out = world.run(move |c| {
            let x1 = x1rc.clone();
            let preset = MatrixPreset::poisson2d(8, 8);
            async move {
                let rank = c.rank();
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::NonBlocking);
                let pat = SpmvPattern::build(&preset, part, rank, 0);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let a = DistMatrix::build(&preset, part, rank, 0, pkg);
                let (s, e) = part.range(rank);
                let y = a.spmv(&c, &x1[s..e]).await;
                a.spmv(&c, &y).await
            }
        });
        let got: Vec<f64> = out.results.into_iter().flatten().collect();
        for (g, e) in got.iter().zip(&y2) {
            assert!((g - e).abs() < 1e-9);
        }
    }

    #[test]
    fn local_diag_extracts_diagonal() {
        let preset = MatrixPreset::poisson2d(4, 4);
        let part = Partition::new(16, 2);
        // single-rank world just to form the pkg quickly
        let world = World::new(Topology::quartz(1, 2), CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let preset = MatrixPreset::poisson2d(4, 4);
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::Personalized);
                let pat = SpmvPattern::build(&preset, part, c.rank(), 0);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let a = DistMatrix::build(&preset, part, c.rank(), 0, pkg);
                a.local_diag()
            }
        });
        for d in out.results.iter().flatten() {
            assert_eq!(*d, 4.0);
        }
    }
}
