//! Weighted Jacobi iteration on a [`DistMatrix`] (diagonally-dominant
//! generator matrices converge unweighted; ω is exposed anyway).

use crate::mpi::{Comm, ReduceOp};

use super::dist::{DistMatrix, LocalSpmv};

/// Run `iters` Jacobi sweeps of `A x = b` starting from zero; returns the
/// final local `x` and the global residual 2-norm after each sweep.
pub async fn jacobi(
    comm: &Comm,
    a: &DistMatrix,
    b: &[f64],
    kernel: &impl LocalSpmv,
    iters: usize,
    omega: f64,
) -> (Vec<f64>, Vec<f64>) {
    let n = a.local_n();
    assert_eq!(b.len(), n);
    let diag = a.local_diag();
    let mut x = vec![0.0; n];
    let mut history = Vec::with_capacity(iters);
    for _ in 0..iters {
        let ax = a.spmv_with(comm, &x, kernel).await;
        let mut local_sq = 0.0;
        for i in 0..n {
            let r = b[i] - ax[i];
            local_sq += r * r;
            x[i] += omega * r / diag[i];
        }
        let glob = comm
            .allreduce(vec![local_sq.to_bits()], ReduceOp::FSum)
            .await;
        history.push(f64::from_bits(glob[0]).sqrt());
    }
    (x, history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::mpix::{MpixComm, MpixInfo, SddeAlgorithm};
    use crate::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
    use crate::solver::dist::CsrLocal;
    use crate::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};

    #[test]
    fn jacobi_converges_on_diag_dominant() {
        let preset = MatrixPreset::fault_639_like().scaled(4000);
        let topo = Topology::quartz(2, 3);
        let part = Partition::new(preset.n, topo.nranks());
        let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let preset = MatrixPreset::fault_639_like().scaled(4000);
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityNonBlocking);
                let pat = SpmvPattern::build(&preset, part, c.rank(), 2);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let a = DistMatrix::build(&preset, part, c.rank(), 2, pkg);
                let b = vec![1.0; a.local_n()];
                let (_, hist) = jacobi(&c, &a, &b, &CsrLocal(&a.local), 30, 1.0).await;
                hist
            }
        });
        let hist = &out.results[0];
        assert!(hist[0] > 0.0);
        assert!(
            hist.last().unwrap() < &(hist[0] * 1e-6),
            "no convergence: {hist:?}"
        );
        // all ranks agree on the global residual
        for h in &out.results {
            assert_eq!(h, hist);
        }
    }
}
