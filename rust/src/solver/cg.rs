//! Conjugate gradients on a [`DistMatrix`] (SPD matrices, e.g. the
//! Poisson2D preset). Global dot products run over the simulated
//! allreduce; local compute goes through the pluggable kernel — in the E2E
//! example that kernel is the AOT-compiled JAX/Pallas artifact.

use crate::mpi::{Comm, ReduceOp};

use super::dist::{DistMatrix, LocalSpmv};

async fn gdot(comm: &Comm, a: &[f64], b: &[f64]) -> f64 {
    let local: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let g = comm.allreduce(vec![local.to_bits()], ReduceOp::FSum).await;
    f64::from_bits(g[0])
}

/// CG for `A x = b` from zero start; stops at `tol` (relative residual) or
/// `max_iters`. Returns local `x` and the residual-norm history.
pub async fn cg(
    comm: &Comm,
    a: &DistMatrix,
    b: &[f64],
    kernel: &impl LocalSpmv,
    max_iters: usize,
    tol: f64,
) -> (Vec<f64>, Vec<f64>) {
    let n = a.local_n();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut rs = gdot(comm, &r, &r).await;
    let rs0 = rs.sqrt().max(f64::MIN_POSITIVE);
    let mut history = vec![rs.sqrt()];
    for _ in 0..max_iters {
        if rs.sqrt() / rs0 < tol {
            break;
        }
        let ap = a.spmv_with(comm, &p, kernel).await;
        let alpha = rs / gdot(comm, &p, &ap).await;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = gdot(comm, &r, &r).await;
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        history.push(rs.sqrt());
    }
    (x, history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::mpix::{MpixComm, MpixInfo, SddeAlgorithm};
    use crate::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
    use crate::solver::dist::CsrLocal;
    use crate::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};
    use std::rc::Rc;

    #[test]
    fn cg_solves_poisson() {
        let preset = MatrixPreset::poisson2d(20, 10);
        let topo = Topology::quartz(2, 4);
        let part = Partition::new(preset.n, topo.nranks());
        // reference solution via sequential CG on the full matrix
        let a_seq = preset.to_csr(0);
        let b_glob: Vec<f64> = (0..preset.n).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let bg = Rc::new(b_glob.clone());
        let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let bg = bg.clone();
            let preset = MatrixPreset::poisson2d(20, 10);
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::LocalityPersonalized);
                let pat = SpmvPattern::build(&preset, part, c.rank(), 0);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let a = DistMatrix::build(&preset, part, c.rank(), 0, pkg);
                let (s, e) = part.range(c.rank());
                let (x, hist) = cg(&c, &a, &bg[s..e], &CsrLocal(&a.local), 500, 1e-10).await;
                (x, hist)
            }
        });
        // residual dropped by 10 orders
        let hist = &out.results[0].1;
        assert!(hist.last().unwrap() / hist[0] < 1e-9, "{hist:?}");
        // assemble x and check A x = b
        let x_glob: Vec<f64> = out.results.iter().flat_map(|(x, _)| x.clone()).collect();
        let ax = a_seq.spmv(&x_glob);
        for i in 0..preset.n {
            assert!((ax[i] - b_glob[i]).abs() < 1e-6, "row {i}");
        }
    }
}
