//! Distributed solvers — the consumers that prove SDDE-formed communication
//! packages correct end to end: a [`dist::DistMatrix`] performs halo
//! exchanges over the pattern the SDDE discovered, and [`jacobi`]/[`cg`]
//! iterate it to convergence. Local per-rank compute is pluggable
//! ([`LocalSpmv`]): a pure-rust CSR kernel, or the AOT-compiled JAX/Pallas
//! artifact via [`crate::runtime`] (the E2E example).

pub mod cg;
pub mod dist;
pub mod jacobi;

pub use cg::cg;
pub use dist::{CsrLocal, DistMatrix, LocalSpmv};
pub use jacobi::jacobi;
