//! # sdde — A More Scalable Sparse Dynamic Data Exchange
//!
//! Reproduction of Geyko, Collom, Schafer, Bridges, Bienz,
//! *“A More Scalable Sparse Dynamic Data Exchange”* (2023): the
//! `MPIX_Alltoall_crs` / `MPIX_Alltoallv_crs` sparse dynamic data exchange
//! (SDDE) APIs and the five SDDE algorithms (personalized, non-blocking,
//! RMA, locality-aware personalized, locality-aware non-blocking), built on
//! top of a deterministic virtual-time cluster simulator.
//!
//! ## Layer map (see DESIGN.md)
//!
//! * [`simnet`] — substrate: deterministic single-threaded async executor
//!   with a virtual clock, hierarchical topology (node/socket/core), a
//!   tiered LogGP-with-matching network cost model, and seeded fault plans
//!   ([`simnet::fault`]: latency jitter, stragglers, forced rendezvous,
//!   duplicate delivery — off by default, bit-identical when off).
//! * [`mpi`] — substrate: a simulated MPI (p2p with unexpected-message
//!   queues and eager/rendezvous protocols, collectives built from p2p,
//!   one-sided RMA windows), plus the hang-diagnosis layer
//!   ([`mpi::watchdog`]): a virtual-time quiescence watchdog and
//!   [`mpi::WaitGraph`] stall reports (per-rank blocked ops, near-miss
//!   unexpected messages, wait-cycle detection).
//! * [`mpix`] — **the paper's contribution**: the MPI Advance-style SDDE
//!   API and all five algorithms.
//! * [`mpix::dispatch`] — evidence-driven algorithm selection: typed
//!   [`mpix::PatternStats`] → [`mpix::Selection`] decisions, scored by a
//!   calibrated [`mpix::DispatchModel`] (fault-inflation + critical-path
//!   wait evidence) with a bit-identical heuristic fallback when no model
//!   is loaded.
//! * [`mpix::neighbor`] — the consumer side: distributed-graph topology
//!   communicators ([`mpix::NeighborComm`]) and persistent (standard +
//!   locality-aware) neighbor alltoallv built from SDDE-formed patterns.
//! * [`sparse`] — sparse-matrix substrate: CSR, synthetic SuiteSparse
//!   analogs, row-wise partitioning, and communication-package formation
//!   (the paper's motivating use case).
//! * [`solver`] — distributed SpMV / Jacobi / CG consumers that prove the
//!   SDDE-formed patterns correct end to end.
//! * [`runtime`] — PJRT (XLA) artifact loading so the solver's local
//!   compute runs the AOT-compiled JAX/Pallas kernels from rust.
//! * [`trace`] — observability: per-`World` typed event recording on the
//!   virtual clock (sends, matches, waits, collective rounds, RMA, CPU),
//!   per-tier × per-tag-family rollups, Chrome-trace/CSV exporters and a
//!   happens-before critical-path extractor. Off by default; the bench
//!   layer derives its traffic metrics from it.
//! * [`bench`] — the figure-regeneration harness (Figs. 5–8 of the paper).

pub mod bench;
pub mod mpi;
pub mod mpix;
pub mod runtime;
pub mod simnet;
pub mod solver;
pub mod sparse;
pub mod trace;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::mpi::{Comm, Payload, Tag, WaitGraph, World, ANY_SOURCE, ANY_TAG};
    pub use crate::mpix::{
        alltoall_crs, alltoallv_crs, select_algorithm, CrsArgs, CrsResult, CrsvArgs,
        CrsvResult, DispatchModel, MpixComm, MpixInfo, NeighborAlltoallv, NeighborComm,
        NeighborMethod, PatternStats, SddeAlgorithm, Selection, SelectionSource,
    };
    pub use crate::simnet::{
        CostModel, FaultPlan, FaultProfile, MpiFlavor, RegionKind, Tier, Time, Topology,
    };
    pub use crate::trace::{Trace, TraceConfig, TraceSummary};
}
