//! Minimal property-testing harness (the offline vendor mirror has no
//! `proptest`, so we roll a seeded-case runner with failure reporting and
//! a simple halving shrinker for sized cases).
//!
//! Usage:
//! ```ignore
//! prop::check(100, |rng| {
//!     let n = 1 + rng.usize_below(64);
//!     /* build inputs from rng, assert invariant, return Ok(()) or Err(msg) */
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `cases` randomized cases of `f`. Panics with the failing seed on the
/// first failure so the case can be replayed with [`replay`].
pub fn check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = std::env::var("SDDE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed (case {case}, seed {seed}): {msg}\n\
                 replay with SDDE_PROP_SEED={seed} and 1 case"
            );
        }
    }
}

/// Replay a single seed (for debugging a failure reported by [`check`]).
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed (seed {seed}): {msg}");
    }
}

/// Run a *sized* property at shrinking sizes: tries `size` first and on
/// failure retries smaller sizes to report the smallest failing size.
pub fn check_sized<F>(cases: u64, max_size: usize, mut f: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let base = std::env::var("SDDE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xBEEF_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B9));
        let size = 1 + (Rng::new(seed).usize_below(max_size));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, size) {
            // Shrink: halve the size until it passes; report smallest failure.
            let mut failing = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                match f(&mut rng, s) {
                    Err(m) => failing = (s, m),
                    Ok(()) => break,
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "sized property failed (case {case}, seed {seed}, smallest failing size {}): {}",
                failing.0, failing.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(50, |rng| {
            if rng.below(10) < 9 {
                Ok(())
            } else {
                Err("hit the 10% case".into())
            }
        });
    }

    #[test]
    fn sized_property_passes() {
        check_sized(20, 128, |rng, size| {
            let mut v: Vec<u64> = (0..size).map(|_| rng.below(1000)).collect();
            v.sort_unstable();
            for w in v.windows(2) {
                if w[0] > w[1] {
                    return Err("sort broken".into());
                }
            }
            Ok(())
        });
    }
}
