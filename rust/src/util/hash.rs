//! A fast, non-cryptographic hasher for the simulator's hot-path maps
//! (rustc-hash/FxHash idiom, reimplemented because the offline vendor
//! mirror carries no external crates).
//!
//! The simulated-MPI matching engine keys its unexpected/posted-queue
//! buckets by `(src, tag)`; with SipHash the per-message index upkeep
//! would cost more than the linear scans it replaces at typical queue
//! depths. FxHash is a single multiply-xor per word — a few ns per op.
//! Host-side only: hashing never influences virtual time (bucket *order*
//! is always arrival/post order, never iteration order of a map).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher over machine words (the `rustc-hash` constant).
#[derive(Default)]
pub struct FxHasher(u64);

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.0 = (self.0.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` with the fast hasher.
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<(usize, u32), u64> = FxHashMap::default();
        for i in 0..1000usize {
            m.insert((i, (i * 7) as u32), i as u64);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000usize {
            assert_eq!(m.get(&(i, (i * 7) as u32)), Some(&(i as u64)));
        }
        assert_eq!(m.remove(&(3, 21)), Some(3));
        assert!(!m.contains_key(&(3, 21)));
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        // Not a collision-resistance claim — just a sanity check that the
        // hasher actually mixes (a constant hash would still be correct but
        // degrade every bucket op to a scan).
        let mut set = FxHashSet::default();
        for src in 0..64usize {
            for tag in 0..64u32 {
                let mut h = FxHasher::default();
                h.write_usize(src);
                h.write_u32(tag);
                set.insert(h.finish());
            }
        }
        assert!(set.len() > 4000, "only {} distinct hashes", set.len());
    }

    #[test]
    fn byte_write_matches_no_panic() {
        let mut h = FxHasher::default();
        h.write(b"hello, unexpected queue");
        let _ = h.finish();
    }
}
