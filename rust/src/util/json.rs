//! Minimal JSON reader/writer helpers (the build is offline: no `serde`).
//!
//! The tree type [`Json`] plus a recursive-descent [`parse`] cover what the
//! dispatch-model files need — objects, arrays, strings, f64 numbers,
//! booleans, null — with standard escape handling (including `\uXXXX` and
//! surrogate pairs). Writing stays with hand-formatted strings at the call
//! sites (like the trace and bench exporters); [`escape`] is the shared
//! string-escaper for that direction.

/// A parsed JSON value. Numbers are always `f64` (the model files carry
/// scores and small counts; integers up to 2^53 round-trip exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match; our writers never duplicate keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (quotes not included).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: s.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json error at byte {}: {msg}", self.i)
    }

    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: the low half must follow.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad unicode escape"))?,
                            );
                            continue; // hex4 already advanced past the digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8 by construction).
                    let rest = std::str::from_utf8(&self.b[self.i..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits (after `\u`); leaves the cursor past them.
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let text = std::str::from_utf8(&self.b[self.i..self.i + 4]).unwrap();
        let v = u32::from_str_radix(text, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let v = parse(r#"{"a": [1, -2.5, true, null], "b": {"c": "x"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].as_f64(),
            Some(-2.5)
        );
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].as_bool(),
            Some(true)
        );
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "quote\" back\\ tab\t nl\n unicode\u{1F600}";
        let doc = format!("\"{}\"", escape(original));
        assert_eq!(parse(&doc).unwrap().as_str(), Some(original));
        // Explicit \u escapes, including a surrogate pair.
        let v = parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str(), Some("A\u{1F600}"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "12 34", "\"unterminated", "nul",
            r#""\q""#,
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn numbers_parse_exactly() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-0.125").unwrap().as_f64(), Some(-0.125));
    }
}
