//! Small self-contained utilities (offline build: no external crates).

pub mod args;
pub mod fmt;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use args::Args;
pub use hash::{FxHashMap, FxHashSet, FxHasher};
pub use rng::{derive_seed, Rng};
