//! Human-readable formatting of times, byte counts and aligned tables.

/// Format a virtual-time duration in nanoseconds with an adaptive unit.
pub fn ns(t: u64) -> String {
    let t = t as f64;
    if t < 1e3 {
        format!("{t:.0} ns")
    } else if t < 1e6 {
        format!("{:.2} us", t / 1e3)
    } else if t < 1e9 {
        format!("{:.3} ms", t / 1e6)
    } else {
        format!("{:.4} s", t / 1e9)
    }
}

/// Format a byte count with an adaptive unit.
pub fn bytes(b: u64) -> String {
    let b = b as f64;
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Render rows as an aligned plain-text table (first row = header).
pub fn table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let mut out = String::new();
    for (ri, r) in rows.iter().enumerate() {
        for (i, c) in r.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(c);
            if i + 1 < r.len() {
                for _ in 0..widths[i].saturating_sub(c.len()) {
                    out.push(' ');
                }
            }
        }
        out.push('\n');
        if ri == 0 {
            let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
            for _ in 0..total {
                out.push('-');
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ns_units() {
        assert_eq!(ns(500), "500 ns");
        assert_eq!(ns(1_500), "1.50 us");
        assert_eq!(ns(2_500_000), "2.500 ms");
        assert_eq!(ns(3_000_000_000), "3.0000 s");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(10), "10 B");
        assert_eq!(bytes(2048), "2.0 KiB");
    }

    #[test]
    fn table_aligns() {
        let t = table(&[
            vec!["a".into(), "bb".into()],
            vec!["ccc".into(), "d".into()],
        ]);
        assert!(t.contains("a    bb"));
        assert!(t.contains("ccc  d"));
    }
}
