//! Minimal CLI argument parser (the offline vendor mirror has no `clap`).
//!
//! Supports `--key value`, `--key=value`, bare `--switch`es and positional
//! arguments. Unknown flags are collected and can be rejected by callers.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.entry(stripped.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Last value of `--key` (flags may repeat; last wins).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// `--key` present at all (switch)?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).filter(|s| !s.is_empty()).unwrap_or(default)
    }

    /// Parse `--key` as T or fall back.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Parse `--key` through `parser`. Absent (or empty) flags yield
    /// `default`; a present-but-invalid value is a hard error carrying the
    /// parser's message (which should list the valid spellings) — never a
    /// silent fallback.
    pub fn get_with<T>(
        &self,
        key: &str,
        default: T,
        parser: impl Fn(&str) -> Result<T, String>,
    ) -> Result<T, String> {
        match self.get(key).filter(|s| !s.is_empty()) {
            None => Ok(default),
            Some(s) => parser(s).map_err(|e| format!("bad --{key} '{s}': {e}")),
        }
    }

    /// Parse every element of the comma-separated `--key` list through
    /// `parser` (same error contract as [`Args::get_with`]). The flag being
    /// absent yields `default`.
    pub fn get_list_with<T>(
        &self,
        key: &str,
        default: Vec<T>,
        parser: impl Fn(&str) -> Result<T, String>,
    ) -> Result<Vec<T>, String> {
        match self.get_list(key) {
            None => Ok(default),
            Some(items) => items
                .iter()
                .map(|s| parser(s).map_err(|e| format!("bad --{key} '{s}': {e}")))
                .collect(),
        }
    }

    /// Comma-separated list value.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn kv_styles() {
        let a = parse("figures --fig 7 --quick --out=results --nodes 2,4,8");
        assert_eq!(a.positional, vec!["figures"]);
        assert_eq!(a.get("fig"), Some("7"));
        assert!(a.has("quick"));
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(
            a.get_list("nodes").unwrap(),
            vec!["2".to_string(), "4".into(), "8".into()]
        );
    }

    #[test]
    fn parsed_and_defaults() {
        let a = parse("--ppn 16");
        assert_eq!(a.get_parsed("ppn", 32usize), 16);
        assert_eq!(a.get_parsed("seed", 42u64), 42);
        assert_eq!(a.get_or("mpi", "mvapich2"), "mvapich2");
    }

    #[test]
    fn get_with_rejects_bad_values_loudly() {
        let parse_pos = |s: &str| {
            s.parse::<usize>()
                .ok()
                .filter(|&v| v > 0)
                .ok_or_else(|| "want a positive integer".to_string())
        };
        let a = parse("--nodes 2,x,8 --ppn 4");
        assert_eq!(a.get_with("ppn", 32, parse_pos).unwrap(), 4);
        assert_eq!(a.get_with("seed", 7, parse_pos).unwrap(), 7); // absent
        let err = a.get_list_with("nodes", vec![], parse_pos).unwrap_err();
        assert!(err.contains("--nodes 'x'"), "{err}");
        assert_eq!(
            a.get_list_with("iters", vec![1, 16], parse_pos).unwrap(),
            vec![1, 16]
        );
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--quick --fig 5");
        assert!(a.has("quick"));
        assert_eq!(a.get("fig"), Some("5"));
    }
}
