//! Minimal CLI argument parser (the offline vendor mirror has no `clap`).
//!
//! Supports `--key value`, `--key=value`, bare `--switch`es and positional
//! arguments. Unknown flags are collected and can be rejected by callers.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.entry(stripped.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Last value of `--key` (flags may repeat; last wins).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// `--key` present at all (switch)?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).filter(|s| !s.is_empty()).unwrap_or(default)
    }

    /// Parse `--key` as T or fall back.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list value.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn kv_styles() {
        let a = parse("figures --fig 7 --quick --out=results --nodes 2,4,8");
        assert_eq!(a.positional, vec!["figures"]);
        assert_eq!(a.get("fig"), Some("7"));
        assert!(a.has("quick"));
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(
            a.get_list("nodes").unwrap(),
            vec!["2".to_string(), "4".into(), "8".into()]
        );
    }

    #[test]
    fn parsed_and_defaults() {
        let a = parse("--ppn 16");
        assert_eq!(a.get_parsed("ppn", 32usize), 16);
        assert_eq!(a.get_parsed("seed", 42u64), 42);
        assert_eq!(a.get_or("mpi", "mvapich2"), "mvapich2");
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--quick --fig 5");
        assert!(a.has("quick"));
        assert_eq!(a.get("fig"), Some("5"));
    }
}
