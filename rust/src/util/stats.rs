//! Tiny descriptive-statistics helpers for the bench harness.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            stddev: var.sqrt(),
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

/// Geometric mean (used for speedup aggregation across matrices).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_edges() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }
}
