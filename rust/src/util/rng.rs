//! Deterministic PRNG (xoshiro256** seeded by splitmix64).
//!
//! The whole reproduction is deterministic: same seed → same matrices, same
//! communication patterns, same virtual times. We hand-roll the generator
//! because the offline vendor mirror has no `rand` crate.

/// xoshiro256** — fast, high-quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Key derivation: map `(seed, stream)` to an independent child seed.
///
/// Both inputs pass through full splitmix64 chains before mixing, so
/// child seeds for adjacent stream ids share no statistical structure
/// (unlike the cheap XOR fold in [`Rng::stream`], which is kept verbatim
/// because pattern generation depends on its exact output). The function
/// composes: `derive_seed(derive_seed(s, cell), rank)` yields
/// per-(cell, rank) streams — the fault layer uses exactly that shape so
/// `--jobs N` chaos sweeps stay byte-identical to serial runs.
pub fn derive_seed(seed: u64, stream: u64) -> u64 {
    let mut a = seed;
    let h = splitmix64(&mut a);
    let mut b = h ^ stream.wrapping_mul(0xD1B54A32D192ED03).rotate_left(29);
    let lo = splitmix64(&mut b);
    let hi = splitmix64(&mut b);
    lo ^ hi.rotate_left(32)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per rank) from this seed.
    pub fn stream(seed: u64, stream: u64) -> Self {
        Rng::new(seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17))
    }

    /// Strongly derived independent stream via [`derive_seed`]. Prefer
    /// this for new code (the fault layer's per-(cell, rank) streams);
    /// [`Rng::stream`] stays as-is for output compatibility.
    pub fn substream(seed: u64, stream: u64) -> Self {
        Rng::new(derive_seed(seed, stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value; simple, fine for gen).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Geometric-ish power-law sample in `[1, max]` with exponent `alpha`.
    pub fn power_law(&mut self, max: u64, alpha: f64) -> u64 {
        // Inverse-CDF of a bounded Pareto on [1, max].
        let u = self.f64();
        let a = 1.0 - alpha;
        let x = ((max as f64).powf(a) * u + (1.0 - u)).powf(1.0 / a);
        (x as u64).clamp(1, max)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `[0, n)` (k << n expected).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut seen = std::collections::BTreeSet::new();
        while seen.len() < k {
            seen.insert(self.usize_below(n));
        }
        seen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn sample_distinct_is_distinct_sorted() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100usize, 10usize), (10, 10), (50, 40), (1, 1), (5, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::stream(42, 0);
        let mut b = Rng::stream(42, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn derive_seed_is_deterministic_and_stream_sensitive() {
        assert_eq!(derive_seed(42, 7), derive_seed(42, 7));
        assert_ne!(derive_seed(42, 7), derive_seed(42, 8));
        assert_ne!(derive_seed(42, 7), derive_seed(43, 7));
    }

    #[test]
    fn derive_seed_decorrelates_adjacent_streams() {
        // Adjacent stream ids must differ in many bits (a weak XOR fold
        // would leave low-bit structure); require a sane Hamming distance.
        for s in 0..16u64 {
            let d = derive_seed(1, s) ^ derive_seed(1, s + 1);
            assert!(d.count_ones() >= 12, "stream {s}: weak mix {d:#x}");
        }
    }

    #[test]
    fn derive_seed_composes_to_distinct_grids() {
        // (cell, rank) grid: all children pairwise distinct.
        let mut seen = std::collections::BTreeSet::new();
        for cell in 0..8u64 {
            for rank in 0..8u64 {
                assert!(seen.insert(derive_seed(derive_seed(9, cell), rank)));
            }
        }
    }

    #[test]
    fn substream_sequences_are_independent() {
        let mut a = Rng::substream(5, 0);
        let mut b = Rng::substream(5, 1);
        let mut same = 0;
        for _ in 0..64 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn power_law_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let x = r.power_law(64, 2.1);
            assert!((1..=64).contains(&x));
        }
    }
}
