//! One builder for every single-world bench run.
//!
//! The bench layer used to grow a new free function per axis combination
//! (`run_once`, `run_once_stats`, `run_once_stats_faulted`,
//! `run_once_traced_faulted`, `run_halo_once_faulted`, ...). [`RunSpec`]
//! collapses the axes — pattern × algorithm × faults × trace × dispatch
//! model — into one value with two executors:
//!
//! * [`RunSpec::run_sdde`] — one timed SDDE on a fresh world → [`SddeRun`]
//!   (max per-rank time, trace rollup and optional events, host stats).
//! * [`RunSpec::run_halo`] — pattern formation + steady-state halo loop →
//!   [`HaloRun`] (setup/loop times, inter-node sends, host stats).
//!
//! Figures, neighbor, chaos and calibrate sweeps all build their cells
//! from specs; the legacy `run_once` / `run_once_traced` /
//! `run_halo_once` entry points survive as thin wrappers for external
//! callers (tests, benches, examples).
//!
//! Every world a spec builds arms the virtual-time quiescence watchdog
//! when the `SDDE_WATCHDOG` environment variable is set (a horizon in
//! virtual ns): a CI hang then dies with a rendered
//! [`crate::mpi::WaitGraph`] in the log instead of a dead timeout — the
//! ROADMAP's watchdog-guided triage.

use std::rc::Rc;

use super::figures::Variant;
use super::neighbor::HaloMethod;
use crate::mpi::World;
use crate::mpix::{
    alltoall_crs, alltoallv_crs, DispatchModel, IntraAlgo, MpixComm, MpixInfo,
    NeighborMethod, SddeAlgorithm,
};
use crate::simnet::{CostModel, FaultPlan, MpiFlavor, RegionKind, SimStats, Time, Topology};
use crate::solver::DistMatrix;
use crate::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};
use crate::trace::{Trace, TraceConfig, TraceSummary};

/// Watchdog horizon from `SDDE_WATCHDOG` (virtual ns); unset/invalid = no
/// watchdog, matching behavior before the variable existed.
pub(crate) fn watchdog_from_env() -> Option<Time> {
    std::env::var("SDDE_WATCHDOG")
        .ok()
        .and_then(|s| s.trim().parse::<Time>().ok())
        .filter(|&h| h > 0)
}

/// Everything that parameterizes one simulated bench run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub topo: Topology,
    pub flavor: MpiFlavor,
    pub algo: SddeAlgorithm,
    pub region: RegionKind,
    pub intra: IntraAlgo,
    /// Pattern seed (halo runs build their patterns internally).
    pub seed: u64,
    pub faults: Option<FaultPlan>,
    pub trace: TraceConfig,
    /// Evidence model for `SddeAlgorithm::Dispatch`; `None` = legacy
    /// heuristic (bit-identical picks).
    pub dispatch: Option<DispatchModel>,
    /// Noise regime handed to model-driven dispatch (fault-profile name).
    pub noise: Option<String>,
    /// Virtual-time quiescence horizon; defaults from `SDDE_WATCHDOG`.
    pub watchdog: Option<Time>,
}

impl RunSpec {
    pub fn new(topo: Topology, flavor: MpiFlavor) -> RunSpec {
        RunSpec {
            topo,
            flavor,
            algo: SddeAlgorithm::Dispatch,
            region: RegionKind::Node,
            intra: IntraAlgo::Personalized,
            seed: 2023,
            faults: None,
            trace: TraceConfig::counters_only(),
            dispatch: None,
            noise: None,
            watchdog: watchdog_from_env(),
        }
    }

    pub fn algo(mut self, algo: SddeAlgorithm) -> RunSpec {
        self.algo = algo;
        self
    }

    pub fn region(mut self, region: RegionKind) -> RunSpec {
        self.region = region;
        self
    }

    pub fn intra(mut self, intra: IntraAlgo) -> RunSpec {
        self.intra = intra;
        self
    }

    pub fn seed(mut self, seed: u64) -> RunSpec {
        self.seed = seed;
        self
    }

    pub fn faults(mut self, faults: Option<FaultPlan>) -> RunSpec {
        self.faults = faults;
        self
    }

    pub fn trace(mut self, trace: TraceConfig) -> RunSpec {
        self.trace = trace;
        self
    }

    pub fn dispatch(mut self, model: Option<DispatchModel>) -> RunSpec {
        self.dispatch = model;
        self
    }

    pub fn noise(mut self, noise: Option<String>) -> RunSpec {
        self.noise = noise;
        self
    }

    pub fn watchdog(mut self, horizon: Option<Time>) -> RunSpec {
        self.watchdog = horizon;
        self
    }

    /// The `MpixInfo` every rank of this spec's worlds uses.
    fn info(&self, model: Option<Rc<DispatchModel>>) -> MpixInfo {
        MpixInfo {
            algorithm: self.algo,
            region: self.region,
            intra: self.intra,
            dispatch_model: model,
            dispatch_noise: self.noise.clone(),
            ..MpixInfo::default()
        }
    }

    fn build_world(&self, trace: TraceConfig) -> World {
        let mut b = World::builder(self.topo.clone(), CostModel::preset(self.flavor))
            .trace(trace)
            .faults(self.faults);
        if let Some(h) = self.watchdog {
            b = b.watchdog(h);
        }
        b.build()
    }

    /// Run one timed SDDE (all ranks aligned by a barrier; only the
    /// exchange is on the clock).
    pub fn run_sdde(&self, variant: Variant, patterns: Rc<Vec<SpmvPattern>>) -> SddeRun {
        let trace = self.trace;
        let world = self.build_world(trace);
        let region = self.region;
        let model = self.dispatch.clone().map(Rc::new);
        let spec_info = self.info(model);
        let out = world.run(move |c| {
            let patterns = patterns.clone();
            let info = spec_info.clone();
            async move {
                let mx = MpixComm::new(c.clone(), region);
                let pat = &patterns[c.rank()];
                // Align all ranks, then time only the exchange itself.
                c.barrier().await;
                let t0 = c.now();
                match variant {
                    Variant::ConstSize => {
                        let args = pat.crs_size_args();
                        let r = alltoall_crs(&mx, &info, &args).await.unwrap();
                        std::hint::black_box(&r);
                    }
                    Variant::Variable => {
                        let args = pat.crsv_args();
                        let r = alltoallv_crs(&mx, &info, &args).await.unwrap();
                        std::hint::black_box(&r);
                    }
                }
                c.now() - t0
            }
        });
        if trace.counters {
            // The rollup must mirror the legacy counters bit-for-bit
            // (invariant 5; also proven by tests/trace_conservation.rs).
            debug_assert_eq!(out.trace.summary.user_msgs(), out.counters.user_msgs);
            debug_assert_eq!(out.trace.summary.user_bytes(), out.counters.user_bytes);
            debug_assert_eq!(out.trace.summary.internode_sent, out.counters.internode_sent);
        }
        SddeRun {
            time_ns: out.results.into_iter().max().unwrap_or(0),
            trace: out.trace,
            stats: out.exec_stats,
        }
    }

    /// Run pattern formation plus a steady-state halo-exchange loop.
    /// Counters are always on (the inter-node metric needs them); pass
    /// `TraceConfig::full()` to also keep events.
    pub fn run_halo(&self, method: HaloMethod, iters: usize, preset: Rc<MatrixPreset>) -> HaloRun {
        let trace = if self.trace.is_enabled() {
            self.trace
        } else {
            TraceConfig::counters_only()
        };
        let part = Partition::new(preset.n, self.topo.nranks());
        let world = self.build_world(trace);
        let region = self.region;
        let seed = self.seed;
        let model = self.dispatch.clone().map(Rc::new);
        let spec_info = self.info(model);
        let out = world.run(move |c| {
            let preset = preset.clone();
            let info = spec_info.clone();
            async move {
                let rank = c.rank();
                let mx = MpixComm::new(c.clone(), region);
                let pat = SpmvPattern::build(&preset, part, rank, seed);
                let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
                let mut a = DistMatrix::build(&preset, part, rank, seed, pkg);

                // Engine setup, timed separately from the steady state.
                c.barrier().await;
                let t0 = c.now();
                match method {
                    HaloMethod::P2p => {}
                    HaloMethod::Persistent => a.init_halo(&mx, NeighborMethod::Standard).await,
                    HaloMethod::LocalityPersistent => {
                        a.init_halo(&mx, NeighborMethod::Locality).await
                    }
                }
                let setup = c.now() - t0;

                // Steady state: `iters` halo exchanges of a fixed vector.
                c.barrier().await;
                let sent0 = c.traced_internode_sent(rank);
                let t1 = c.now();
                let (s, e) = part.range(rank);
                let x: Vec<f64> = (s..e).map(|i| (i % 23) as f64 - 11.0).collect();
                let mut sink = 0.0;
                for _ in 0..iters {
                    let x_ext = a.halo_exchange(&c, &x).await;
                    sink += x_ext.last().copied().unwrap_or(0.0);
                }
                let loop_t = c.now() - t1;
                c.barrier().await;
                let sent1 = c.traced_internode_sent(rank);
                std::hint::black_box(sink);
                (setup, loop_t, sent1 - sent0)
            }
        });
        HaloRun {
            setup_ns: out.results.iter().map(|r| r.0).max().unwrap_or(0),
            loop_ns: out.results.iter().map(|r| r.1).max().unwrap_or(0),
            internode_sent: out.results.iter().map(|r| r.2).max().unwrap_or(0),
            stats: out.exec_stats,
        }
    }
}

/// What one [`RunSpec::run_sdde`] measured.
#[derive(Clone, Debug)]
pub struct SddeRun {
    /// Max per-rank virtual time of the SDDE call (ns).
    pub time_ns: Time,
    /// Rollup summary always; events only under `TraceConfig::full`.
    pub trace: Trace,
    /// Executor host-side stats (wall ns, events, polls).
    pub stats: SimStats,
}

impl SddeRun {
    pub fn summary(&self) -> &TraceSummary {
        &self.trace.summary
    }
}

/// What one [`RunSpec::run_halo`] measured.
#[derive(Clone, Debug)]
pub struct HaloRun {
    /// Max per-rank virtual time of the engine setup (0 for legacy p2p).
    pub setup_ns: Time,
    /// Max per-rank virtual time of the whole iteration loop.
    pub loop_ns: Time,
    /// Max per-rank inter-node user messages sent during the loop.
    pub internode_sent: u64,
    pub stats: SimStats,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::figures::FigureId;

    fn small_patterns(topo: &Topology, seed: u64) -> Rc<Vec<SpmvPattern>> {
        let preset = MatrixPreset::cage14_like().scaled(400);
        let part = Partition::new(preset.n, topo.nranks());
        Rc::new(
            (0..topo.nranks())
                .map(|r| SpmvPattern::build(&preset, part, r, seed))
                .collect(),
        )
    }

    #[test]
    fn spec_matches_legacy_wrapper_bit_for_bit() {
        let topo = Topology::quartz(2, 4);
        let patterns = small_patterns(&topo, 2023);
        let fig = FigureId::Fig7;
        let spec = RunSpec::new(topo.clone(), fig.flavor())
            .algo(SddeAlgorithm::LocalityNonBlocking)
            .watchdog(None);
        let a = spec.run_sdde(fig.variant(), patterns.clone());
        let (t, summary) = super::super::figures::run_once(
            topo,
            fig.flavor(),
            SddeAlgorithm::LocalityNonBlocking,
            RegionKind::Node,
            IntraAlgo::Personalized,
            fig.variant(),
            patterns,
        );
        assert_eq!(a.time_ns, t);
        assert_eq!(a.summary().user_msgs(), summary.user_msgs());
    }

    #[test]
    fn trace_mode_keeps_events_without_moving_time() {
        let topo = Topology::quartz(2, 4);
        let patterns = small_patterns(&topo, 2023);
        let spec = RunSpec::new(topo, MpiFlavor::Mvapich2)
            .algo(SddeAlgorithm::NonBlocking)
            .watchdog(None);
        let counters = spec.clone().run_sdde(Variant::Variable, patterns.clone());
        let full = spec
            .trace(TraceConfig::full())
            .run_sdde(Variant::Variable, patterns);
        assert_eq!(counters.time_ns, full.time_ns);
        assert!(counters.trace.events.is_empty());
        assert!(!full.trace.events.is_empty());
    }

    #[test]
    fn halo_spec_runs_all_methods() {
        let topo = Topology::quartz(2, 4);
        let preset = Rc::new(MatrixPreset::cage14_like().scaled(400));
        let spec = RunSpec::new(topo, MpiFlavor::Mvapich2)
            .algo(SddeAlgorithm::LocalityNonBlocking)
            .watchdog(None);
        for method in HaloMethod::ALL {
            let r = spec.run_halo(method, 2, preset.clone());
            assert!(r.loop_ns > 0, "{method:?}");
            if method == HaloMethod::P2p {
                assert_eq!(r.setup_ns, 0);
            }
        }
    }

    #[test]
    fn watchdog_horizon_leaves_results_unchanged() {
        // Arming a generous watchdog must be observationally invisible.
        let topo = Topology::quartz(2, 4);
        let patterns = small_patterns(&topo, 7);
        let base = RunSpec::new(topo.clone(), MpiFlavor::Mvapich2)
            .algo(SddeAlgorithm::Personalized)
            .watchdog(None)
            .run_sdde(Variant::Variable, patterns.clone());
        let dogged = RunSpec::new(topo, MpiFlavor::Mvapich2)
            .algo(SddeAlgorithm::Personalized)
            .watchdog(Some(10_000_000_000))
            .run_sdde(Variant::Variable, patterns);
        assert_eq!(base.time_ns, dogged.time_ns);
    }
}
