//! The neighbor figure: amortized-setup and locality-aggregation wins of
//! the persistent neighborhood collectives in the *steady state*.
//!
//! For each (matrix, topology, halo method, iteration count): form the
//! pattern once with an SDDE, set the exchange engine up (free for legacy
//! p2p; plan negotiation for the persistent methods), then run `iters`
//! halo exchanges and report the per-iteration virtual time plus the
//! per-iteration max inter-node message count — the steady-state analog of
//! the paper's red dots. Sweeping `iters` shows where the persistent
//! setup cost amortizes; sweeping methods shows the locality win.

use std::rc::Rc;

use super::par::{run_cells, timed, CellBench, ProgressSink, SweepBench};
use super::runspec::RunSpec;
use crate::mpix::dispatch;
use crate::mpix::{DispatchModel, SddeAlgorithm};
use crate::simnet::{FaultPlan, MpiFlavor, RegionKind, Time, Topology};
use crate::sparse::{MatrixPreset, Partition, SpmvPattern};

/// Halo-exchange engine under measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HaloMethod {
    /// Legacy per-exchange tagged p2p (the reference path).
    P2p,
    /// Persistent neighbor alltoallv, standard p2p channels.
    Persistent,
    /// Persistent neighbor alltoallv, locality-aware aggregation.
    LocalityPersistent,
}

impl HaloMethod {
    pub const ALL: [HaloMethod; 3] = [
        HaloMethod::P2p,
        HaloMethod::Persistent,
        HaloMethod::LocalityPersistent,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            HaloMethod::P2p => "p2p",
            HaloMethod::Persistent => "persistent",
            HaloMethod::LocalityPersistent => "loc-persistent",
        }
    }

    pub fn parse(s: &str) -> Option<HaloMethod> {
        match s.to_ascii_lowercase().as_str() {
            "p2p" => Some(HaloMethod::P2p),
            "persistent" | "std" | "standard" => Some(HaloMethod::Persistent),
            "loc-persistent" | "locality" | "loc" => Some(HaloMethod::LocalityPersistent),
            _ => None,
        }
    }
}

/// Sweep configuration for the neighbor figure.
#[derive(Clone, Debug)]
pub struct NeighborSweepConfig {
    pub flavor: MpiFlavor,
    pub nodes: Vec<usize>,
    pub ppn: usize,
    pub matrices: Vec<MatrixPreset>,
    pub methods: Vec<HaloMethod>,
    pub iters: Vec<usize>,
    pub region: RegionKind,
    /// SDDE algorithm forming the pattern (identical across methods so
    /// only the steady-state engine differs).
    pub algo: SddeAlgorithm,
    pub seed: u64,
    pub progress: ProgressSink,
    /// Worker threads; one cell per (matrix, nodes, method, iters) tuple.
    pub jobs: usize,
    /// Seeded fault injection for every cell world (chaos sweeps); each
    /// cell derives a child plan from its index, so any `jobs` value
    /// yields byte-identical output. `None` = fault-free.
    pub faults: Option<FaultPlan>,
    /// Evidence model for the per-point `dispatch` column and for
    /// model-driven formation when `algo == Dispatch`.
    pub dispatch: Option<DispatchModel>,
    /// Noise regime handed to model-driven dispatch decisions.
    pub noise: Option<String>,
}

impl NeighborSweepConfig {
    /// Quick default: two topologies, three iteration counts, matrices
    /// shrunk by `div`.
    pub fn quick(flavor: MpiFlavor, div: usize) -> NeighborSweepConfig {
        NeighborSweepConfig {
            flavor,
            nodes: vec![2, 4],
            ppn: 8,
            matrices: vec![
                MatrixPreset::cage14_like().scaled(div),
                MatrixPreset::dielfilterv2clx_like().scaled(div),
            ],
            methods: HaloMethod::ALL.to_vec(),
            iters: vec![1, 16, 256],
            region: RegionKind::Node,
            algo: SddeAlgorithm::LocalityNonBlocking,
            seed: 2023,
            progress: ProgressSink::Silent,
            jobs: 1,
            faults: None,
            dispatch: None,
            noise: None,
        }
    }
}

/// One measured point of the neighbor figure.
#[derive(Clone, Debug, PartialEq)]
pub struct NeighborPoint {
    pub matrix: String,
    pub method: &'static str,
    pub flavor: &'static str,
    pub nodes: usize,
    pub ranks: usize,
    pub iters: usize,
    /// Max per-rank virtual time of the engine setup (0 for legacy p2p).
    pub setup_ns: Time,
    /// Max per-rank virtual time of the whole iteration loop.
    pub loop_ns: Time,
    /// `loop_ns / iters`.
    pub per_iter_ns: f64,
    /// Max over ranks of inter-node user messages sent during the loop,
    /// divided by `iters` (steady-state red dots).
    pub internode_per_iter: f64,
    /// What the dispatch layer picks for this cell's formation pattern
    /// (rank 0's variable-size SDDE regime).
    pub dispatch: &'static str,
}

/// Run one steady-state measurement; returns
/// (max setup ns, max loop ns, max per-rank inter-node sends in the loop).
/// Thin wrapper over [`RunSpec::run_halo`] kept for external callers.
#[allow(clippy::too_many_arguments)]
pub fn run_halo_once(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    method: HaloMethod,
    iters: usize,
    preset: Rc<MatrixPreset>,
    seed: u64,
) -> (Time, Time, u64) {
    let run = RunSpec::new(topo, flavor)
        .algo(algo)
        .region(region)
        .seed(seed)
        .run_halo(method, iters, preset);
    (run.setup_ns, run.loop_ns, run.internode_sent)
}

/// Run the full sweep and return every measured point.
pub fn run_neighbor_sweep(cfg: &NeighborSweepConfig) -> Vec<NeighborPoint> {
    run_neighbor_sweep_bench(cfg).0
}

/// Run the full sweep, returning points plus the host-side cost summary.
/// One cell per (matrix, nodes, method, iters); output and points are
/// identical for every `cfg.jobs` value.
pub fn run_neighbor_sweep_bench(
    cfg: &NeighborSweepConfig,
) -> (Vec<NeighborPoint>, SweepBench) {
    let keys: Vec<(usize, usize, HaloMethod, usize)> = cfg
        .matrices
        .iter()
        .enumerate()
        .flat_map(|(mi, _)| {
            cfg.nodes.iter().flat_map(move |&nodes| {
                cfg.methods.iter().flat_map(move |&method| {
                    cfg.iters.iter().map(move |&iters| (mi, nodes, method, iters))
                })
            })
        })
        .collect();
    let ((cell_out, _), wall_ns) = timed(|| {
        run_cells(cfg.jobs, keys.len(), cfg.progress, |i, pr| {
            let (mi, nodes, method, iters) = keys[i];
            let preset = Rc::new(cfg.matrices[mi].clone());
            let topo = Topology::quartz(nodes, cfg.ppn);
            let ranks = topo.nranks();
            let faults = cfg.faults.map(|p| p.for_cell(i as u64));
            // The dispatch column: rank 0's formation-pattern regime
            // (variable-size — form_commpkg rides MPIX_Alltoallv_crs).
            let part = Partition::new(preset.n, ranks);
            let stats = SpmvPattern::build(&preset, part, 0, cfg.seed)
                .dispatch_stats(&topo, cfg.region, false);
            let pick =
                dispatch::select(cfg.dispatch.as_ref(), &stats, cfg.noise.as_deref());
            let run = RunSpec::new(topo, cfg.flavor)
                .algo(cfg.algo)
                .region(cfg.region)
                .seed(cfg.seed)
                .faults(faults)
                .dispatch(cfg.dispatch.clone())
                .noise(cfg.noise.clone())
                .run_halo(method, iters, preset.clone());
            pr.line(format!(
                "[neighbor] {} nodes={nodes} {:>14} iters={iters:>5}: \
                 {}/iter (setup {})",
                preset.name,
                method.name(),
                crate::util::fmt::ns((run.loop_ns as f64 / iters as f64) as u64),
                crate::util::fmt::ns(run.setup_ns),
            ));
            let point = NeighborPoint {
                matrix: preset.name.clone(),
                method: method.name(),
                flavor: cfg.flavor.name(),
                nodes,
                ranks,
                iters,
                setup_ns: run.setup_ns,
                loop_ns: run.loop_ns,
                per_iter_ns: run.loop_ns as f64 / iters as f64,
                internode_per_iter: run.internode_sent as f64 / iters as f64,
                dispatch: pick.algo.name(),
            };
            let cell = CellBench {
                label: format!(
                    "{} nodes={nodes} {} iters={iters}",
                    preset.name,
                    method.name()
                ),
                host_ns: run.stats.host_ns,
                events_run: run.stats.events_run,
                polls: run.stats.polls,
            };
            (point, cell)
        })
    });
    let (points, cells): (Vec<_>, Vec<_>) = cell_out.into_iter().unzip();
    let bench = SweepBench {
        jobs: cfg.jobs.max(1),
        wall_ns,
        cells,
    };
    (points, bench)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbor_sweep_produces_points() {
        let mut cfg = NeighborSweepConfig::quick(MpiFlavor::Mvapich2, 400);
        cfg.nodes = vec![2];
        cfg.matrices.truncate(1);
        cfg.iters = vec![1, 4];
        let pts = run_neighbor_sweep(&cfg);
        // 1 matrix x 1 node count x 3 methods x 2 iteration counts
        assert_eq!(pts.len(), 6);
        for p in &pts {
            assert!(p.loop_ns > 0, "{p:?}");
            assert!(p.per_iter_ns > 0.0, "{p:?}");
            if p.method == "p2p" {
                assert_eq!(p.setup_ns, 0, "legacy path has no setup: {p:?}");
            }
            // No model loaded: the column is the heuristic's crsv pick.
            assert!(SddeAlgorithm::parse(p.dispatch).is_ok(), "{p:?}");
        }
    }

    // The locality-vs-direct inter-node message assertion lives in
    // tests/neighbor_agreement.rs (steady_state_locality_reduces_
    // internode_messages) — not duplicated here.
}
