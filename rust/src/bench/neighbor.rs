//! The neighbor figure: amortized-setup and locality-aggregation wins of
//! the persistent neighborhood collectives in the *steady state*.
//!
//! For each (matrix, topology, halo method, iteration count): form the
//! pattern once with an SDDE, set the exchange engine up (free for legacy
//! p2p; plan negotiation for the persistent methods), then run `iters`
//! halo exchanges and report the per-iteration virtual time plus the
//! per-iteration max inter-node message count — the steady-state analog of
//! the paper's red dots. Sweeping `iters` shows where the persistent
//! setup cost amortizes; sweeping methods shows the locality win.

use std::rc::Rc;

use super::par::{run_cells, timed, CellBench, ProgressSink, SweepBench};
use crate::mpi::World;
use crate::mpix::{MpixComm, MpixInfo, NeighborMethod, SddeAlgorithm};
use crate::simnet::{CostModel, FaultPlan, MpiFlavor, RegionKind, SimStats, Time, Topology};
use crate::solver::DistMatrix;
use crate::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};
use crate::trace::TraceConfig;

/// Halo-exchange engine under measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HaloMethod {
    /// Legacy per-exchange tagged p2p (the reference path).
    P2p,
    /// Persistent neighbor alltoallv, standard p2p channels.
    Persistent,
    /// Persistent neighbor alltoallv, locality-aware aggregation.
    LocalityPersistent,
}

impl HaloMethod {
    pub const ALL: [HaloMethod; 3] = [
        HaloMethod::P2p,
        HaloMethod::Persistent,
        HaloMethod::LocalityPersistent,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            HaloMethod::P2p => "p2p",
            HaloMethod::Persistent => "persistent",
            HaloMethod::LocalityPersistent => "loc-persistent",
        }
    }

    pub fn parse(s: &str) -> Option<HaloMethod> {
        match s.to_ascii_lowercase().as_str() {
            "p2p" => Some(HaloMethod::P2p),
            "persistent" | "std" | "standard" => Some(HaloMethod::Persistent),
            "loc-persistent" | "locality" | "loc" => Some(HaloMethod::LocalityPersistent),
            _ => None,
        }
    }
}

/// Sweep configuration for the neighbor figure.
#[derive(Clone, Debug)]
pub struct NeighborSweepConfig {
    pub flavor: MpiFlavor,
    pub nodes: Vec<usize>,
    pub ppn: usize,
    pub matrices: Vec<MatrixPreset>,
    pub methods: Vec<HaloMethod>,
    pub iters: Vec<usize>,
    pub region: RegionKind,
    /// SDDE algorithm forming the pattern (identical across methods so
    /// only the steady-state engine differs).
    pub algo: SddeAlgorithm,
    pub seed: u64,
    pub progress: ProgressSink,
    /// Worker threads; one cell per (matrix, nodes, method, iters) tuple.
    pub jobs: usize,
    /// Seeded fault injection for every cell world (chaos sweeps); each
    /// cell derives a child plan from its index, so any `jobs` value
    /// yields byte-identical output. `None` = fault-free.
    pub faults: Option<FaultPlan>,
}

impl NeighborSweepConfig {
    /// Quick default: two topologies, three iteration counts, matrices
    /// shrunk by `div`.
    pub fn quick(flavor: MpiFlavor, div: usize) -> NeighborSweepConfig {
        NeighborSweepConfig {
            flavor,
            nodes: vec![2, 4],
            ppn: 8,
            matrices: vec![
                MatrixPreset::cage14_like().scaled(div),
                MatrixPreset::dielfilterv2clx_like().scaled(div),
            ],
            methods: HaloMethod::ALL.to_vec(),
            iters: vec![1, 16, 256],
            region: RegionKind::Node,
            algo: SddeAlgorithm::LocalityNonBlocking,
            seed: 2023,
            progress: ProgressSink::Silent,
            jobs: 1,
            faults: None,
        }
    }
}

/// One measured point of the neighbor figure.
#[derive(Clone, Debug, PartialEq)]
pub struct NeighborPoint {
    pub matrix: String,
    pub method: &'static str,
    pub flavor: &'static str,
    pub nodes: usize,
    pub ranks: usize,
    pub iters: usize,
    /// Max per-rank virtual time of the engine setup (0 for legacy p2p).
    pub setup_ns: Time,
    /// Max per-rank virtual time of the whole iteration loop.
    pub loop_ns: Time,
    /// `loop_ns / iters`.
    pub per_iter_ns: f64,
    /// Max over ranks of inter-node user messages sent during the loop,
    /// divided by `iters` (steady-state red dots).
    pub internode_per_iter: f64,
}

/// Run one steady-state measurement; returns
/// (max setup ns, max loop ns, max per-rank inter-node sends in the loop).
pub fn run_halo_once(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    method: HaloMethod,
    iters: usize,
    preset: Rc<MatrixPreset>,
    seed: u64,
) -> (Time, Time, u64) {
    let (setup, loop_t, sent, _) =
        run_halo_once_stats(topo, flavor, algo, region, method, iters, preset, seed);
    (setup, loop_t, sent)
}

/// [`run_halo_once`] plus the executor's host-side stats.
#[allow(clippy::too_many_arguments)]
pub fn run_halo_once_stats(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    method: HaloMethod,
    iters: usize,
    preset: Rc<MatrixPreset>,
    seed: u64,
) -> (Time, Time, u64, SimStats) {
    run_halo_once_faulted(topo, flavor, algo, region, method, iters, preset, seed, None)
}

/// [`run_halo_once_stats`] under an optional seeded fault plan (`None` is
/// bit-identical to the unfaulted path).
#[allow(clippy::too_many_arguments)]
pub fn run_halo_once_faulted(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    method: HaloMethod,
    iters: usize,
    preset: Rc<MatrixPreset>,
    seed: u64,
    faults: Option<FaultPlan>,
) -> (Time, Time, u64, SimStats) {
    let part = Partition::new(preset.n, topo.nranks());
    let world = World::builder(topo, CostModel::preset(flavor))
        .trace(TraceConfig::counters_only())
        .faults(faults)
        .build();
    let out = world.run(move |c| {
        let preset = preset.clone();
        async move {
            let rank = c.rank();
            let mx = MpixComm::new(c.clone(), region);
            let info = MpixInfo {
                algorithm: algo,
                region,
                ..MpixInfo::default()
            };
            let pat = SpmvPattern::build(&preset, part, rank, seed);
            let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
            let mut a = DistMatrix::build(&preset, part, rank, seed, pkg);

            // Engine setup, timed separately from the steady state.
            c.barrier().await;
            let t0 = c.now();
            match method {
                HaloMethod::P2p => {}
                HaloMethod::Persistent => a.init_halo(&mx, NeighborMethod::Standard).await,
                HaloMethod::LocalityPersistent => {
                    a.init_halo(&mx, NeighborMethod::Locality).await
                }
            }
            let setup = c.now() - t0;

            // Steady state: `iters` halo exchanges of a fixed vector.
            c.barrier().await;
            let sent0 = c.traced_internode_sent(rank);
            let t1 = c.now();
            let (s, e) = part.range(rank);
            let x: Vec<f64> = (s..e).map(|i| (i % 23) as f64 - 11.0).collect();
            let mut sink = 0.0;
            for _ in 0..iters {
                let x_ext = a.halo_exchange(&c, &x).await;
                sink += x_ext.last().copied().unwrap_or(0.0);
            }
            let loop_t = c.now() - t1;
            c.barrier().await;
            let sent1 = c.traced_internode_sent(rank);
            std::hint::black_box(sink);
            (setup, loop_t, sent1 - sent0)
        }
    });
    let setup = out.results.iter().map(|r| r.0).max().unwrap_or(0);
    let loop_t = out.results.iter().map(|r| r.1).max().unwrap_or(0);
    let sent = out.results.iter().map(|r| r.2).max().unwrap_or(0);
    (setup, loop_t, sent, out.exec_stats)
}

/// Run the full sweep and return every measured point.
pub fn run_neighbor_sweep(cfg: &NeighborSweepConfig) -> Vec<NeighborPoint> {
    run_neighbor_sweep_bench(cfg).0
}

/// Run the full sweep, returning points plus the host-side cost summary.
/// One cell per (matrix, nodes, method, iters); output and points are
/// identical for every `cfg.jobs` value.
pub fn run_neighbor_sweep_bench(
    cfg: &NeighborSweepConfig,
) -> (Vec<NeighborPoint>, SweepBench) {
    let keys: Vec<(usize, usize, HaloMethod, usize)> = cfg
        .matrices
        .iter()
        .enumerate()
        .flat_map(|(mi, _)| {
            cfg.nodes.iter().flat_map(move |&nodes| {
                cfg.methods.iter().flat_map(move |&method| {
                    cfg.iters.iter().map(move |&iters| (mi, nodes, method, iters))
                })
            })
        })
        .collect();
    let ((cell_out, _), wall_ns) = timed(|| {
        run_cells(cfg.jobs, keys.len(), cfg.progress, |i, pr| {
            let (mi, nodes, method, iters) = keys[i];
            let preset = Rc::new(cfg.matrices[mi].clone());
            let topo = Topology::quartz(nodes, cfg.ppn);
            let ranks = topo.nranks();
            let faults = cfg.faults.map(|p| p.for_cell(i as u64));
            let (setup_ns, loop_ns, sent, stats) = run_halo_once_faulted(
                topo,
                cfg.flavor,
                cfg.algo,
                cfg.region,
                method,
                iters,
                preset.clone(),
                cfg.seed,
                faults,
            );
            pr.line(format!(
                "[neighbor] {} nodes={nodes} {:>14} iters={iters:>5}: \
                 {}/iter (setup {})",
                preset.name,
                method.name(),
                crate::util::fmt::ns((loop_ns as f64 / iters as f64) as u64),
                crate::util::fmt::ns(setup_ns),
            ));
            let point = NeighborPoint {
                matrix: preset.name.clone(),
                method: method.name(),
                flavor: cfg.flavor.name(),
                nodes,
                ranks,
                iters,
                setup_ns,
                loop_ns,
                per_iter_ns: loop_ns as f64 / iters as f64,
                internode_per_iter: sent as f64 / iters as f64,
            };
            let cell = CellBench {
                label: format!(
                    "{} nodes={nodes} {} iters={iters}",
                    preset.name,
                    method.name()
                ),
                host_ns: stats.host_ns,
                events_run: stats.events_run,
                polls: stats.polls,
            };
            (point, cell)
        })
    });
    let (points, cells): (Vec<_>, Vec<_>) = cell_out.into_iter().unzip();
    let bench = SweepBench {
        jobs: cfg.jobs.max(1),
        wall_ns,
        cells,
    };
    (points, bench)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbor_sweep_produces_points() {
        let mut cfg = NeighborSweepConfig::quick(MpiFlavor::Mvapich2, 400);
        cfg.nodes = vec![2];
        cfg.matrices.truncate(1);
        cfg.iters = vec![1, 4];
        let pts = run_neighbor_sweep(&cfg);
        // 1 matrix x 1 node count x 3 methods x 2 iteration counts
        assert_eq!(pts.len(), 6);
        for p in &pts {
            assert!(p.loop_ns > 0, "{p:?}");
            assert!(p.per_iter_ns > 0.0, "{p:?}");
            if p.method == "p2p" {
                assert_eq!(p.setup_ns, 0, "legacy path has no setup: {p:?}");
            }
        }
    }

    // The locality-vs-direct inter-node message assertion lives in
    // tests/neighbor_agreement.rs (steady_state_locality_reduces_
    // internode_messages) — not duplicated here.
}
