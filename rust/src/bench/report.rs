//! Rendering of sweep results: per-figure tables (one block per matrix,
//! like the paper's 2×2 figure grids), CSV export, and the §V speedup
//! summary ("up to 20× at 64 nodes").

use std::collections::BTreeSet;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::figures::Point;
use super::neighbor::{HaloMethod, NeighborPoint};
use super::par::SweepBench;
use crate::util::fmt;

/// Render one figure's points as per-matrix tables. Columns: node count,
/// per-algorithm virtual time, and the standard/aggregated max inter-node
/// message counts (the paper's red dots).
pub fn render_figure(title: &str, points: &[Point]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let matrices: Vec<String> = {
        let mut seen = BTreeSet::new();
        points
            .iter()
            .filter(|p| seen.insert(p.matrix.clone()))
            .map(|p| p.matrix.clone())
            .collect()
    };
    let algos: Vec<&'static str> = {
        let mut seen = BTreeSet::new();
        points
            .iter()
            .filter(|p| seen.insert(p.algo))
            .map(|p| p.algo)
            .collect()
    };
    for m in &matrices {
        out.push_str(&format!("\n-- {m} --\n"));
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut header = vec!["nodes".to_string(), "ranks".to_string()];
        header.extend(algos.iter().map(|a| a.to_string()));
        header.push("msgs(std)".into());
        header.push("msgs(agg)".into());
        rows.push(header);
        let node_counts: BTreeSet<usize> = points
            .iter()
            .filter(|p| &p.matrix == m)
            .map(|p| p.nodes)
            .collect();
        for &n in &node_counts {
            let at = |algo: &str| {
                points
                    .iter()
                    .find(|p| &p.matrix == m && p.nodes == n && p.algo == algo)
            };
            let mut row = vec![n.to_string()];
            row.push(
                at(algos[0])
                    .map(|p| p.ranks.to_string())
                    .unwrap_or_default(),
            );
            for a in &algos {
                row.push(
                    at(a)
                        .map(|p| fmt::ns(p.time_ns))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            let std_msgs = ["personalized", "nonblocking", "rma"]
                .iter()
                .filter_map(|a| at(a))
                .map(|p| p.max_internode)
                .max();
            let agg_msgs = ["loc-personalized", "loc-nonblocking"]
                .iter()
                .filter_map(|a| at(a))
                .map(|p| p.max_internode)
                .max();
            row.push(std_msgs.map(|v| v.to_string()).unwrap_or_default());
            row.push(agg_msgs.map(|v| v.to_string()).unwrap_or_default());
            rows.push(row);
        }
        out.push_str(&fmt::table(&rows));
    }
    out.push_str(&speedup_summary(points));
    out
}

/// The paper's §V headline: per matrix at the largest node count, the
/// speedup of the best locality-aware algorithm over the best standard one.
pub fn speedup_summary(points: &[Point]) -> String {
    let mut out = String::from("\n-- speedup at largest scale (loc-aware vs best standard) --\n");
    let matrices: BTreeSet<String> = points.iter().map(|p| p.matrix.clone()).collect();
    for m in matrices {
        let max_nodes = points
            .iter()
            .filter(|p| p.matrix == m)
            .map(|p| p.nodes)
            .max()
            .unwrap_or(0);
        let best = |names: &[&str]| -> Option<u64> {
            points
                .iter()
                .filter(|p| {
                    p.matrix == m && p.nodes == max_nodes && names.contains(&p.algo)
                })
                .map(|p| p.time_ns)
                .min()
        };
        let std = best(&["personalized", "nonblocking", "rma"]);
        let agg = best(&["loc-personalized", "loc-nonblocking"]);
        if let (Some(s), Some(a)) = (std, agg) {
            out.push_str(&format!(
                "{m} @ {max_nodes} nodes: {:.2}x {}\n",
                s as f64 / a as f64,
                if a <= s { "speedup" } else { "(slowdown)" },
            ));
        }
    }
    out
}

/// Render the neighbor figure: per matrix, one row per (node count,
/// iteration count) with per-iteration exchange time per halo method, the
/// persistent setup cost, and the steady-state speedup of the
/// locality-aware engine over legacy p2p.
pub fn render_neighbor_figure(title: &str, points: &[NeighborPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let matrices: Vec<String> = {
        let mut seen = BTreeSet::new();
        points
            .iter()
            .filter(|p| seen.insert(p.matrix.clone()))
            .map(|p| p.matrix.clone())
            .collect()
    };
    let methods: Vec<&'static str> = {
        let mut seen = BTreeSet::new();
        points
            .iter()
            .filter(|p| seen.insert(p.method))
            .map(|p| p.method)
            .collect()
    };
    for m in &matrices {
        out.push_str(&format!("\n-- {m} --\n"));
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut header = vec!["nodes".to_string(), "ranks".to_string(), "iters".to_string()];
        for meth in &methods {
            header.push(format!("{meth}/iter"));
        }
        header.push("setup(pers)".into());
        header.push("setup(loc)".into());
        header.push("msgs/iter p2p".into());
        header.push("msgs/iter loc".into());
        header.push("loc vs p2p".into());
        rows.push(header);
        let keys: BTreeSet<(usize, usize)> = points
            .iter()
            .filter(|p| &p.matrix == m)
            .map(|p| (p.nodes, p.iters))
            .collect();
        for &(nodes, iters) in &keys {
            let at = |method: &str| {
                points.iter().find(|p| {
                    &p.matrix == m && p.nodes == nodes && p.iters == iters && p.method == method
                })
            };
            let mut row = vec![
                nodes.to_string(),
                at(methods[0]).map(|p| p.ranks.to_string()).unwrap_or_default(),
                iters.to_string(),
            ];
            for meth in &methods {
                row.push(
                    at(meth)
                        .map(|p| fmt::ns(p.per_iter_ns as u64))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            // Column keys come from HaloMethod::name() — the same source
            // the sweep stamps into NeighborPoint.method.
            let (p2p, pers, loc) = (
                HaloMethod::P2p.name(),
                HaloMethod::Persistent.name(),
                HaloMethod::LocalityPersistent.name(),
            );
            row.push(at(pers).map(|p| fmt::ns(p.setup_ns)).unwrap_or_default());
            row.push(at(loc).map(|p| fmt::ns(p.setup_ns)).unwrap_or_default());
            row.push(
                at(p2p)
                    .map(|p| format!("{:.1}", p.internode_per_iter))
                    .unwrap_or_default(),
            );
            row.push(
                at(loc)
                    .map(|p| format!("{:.1}", p.internode_per_iter))
                    .unwrap_or_default(),
            );
            row.push(match (at(p2p), at(loc)) {
                (Some(a), Some(b)) if b.per_iter_ns > 0.0 => {
                    format!("{:.2}x", a.per_iter_ns / b.per_iter_ns)
                }
                _ => String::new(),
            });
            rows.push(row);
        }
        out.push_str(&fmt::table(&rows));
    }
    out
}

/// Write neighbor-figure points as CSV (one row per measurement).
pub fn write_neighbor_csv(path: &Path, points: &[NeighborPoint]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    writeln!(
        f,
        "matrix,method,mpi,nodes,ranks,iters,setup_ns,loop_ns,per_iter_ns,internode_per_iter,dispatch"
    )?;
    for p in points {
        writeln!(
            f,
            "{},{},{},{},{},{},{},{},{:.2},{:.2},{}",
            p.matrix,
            p.method,
            p.flavor,
            p.nodes,
            p.ranks,
            p.iters,
            p.setup_ns,
            p.loop_ns,
            p.per_iter_ns,
            p.internode_per_iter,
            p.dispatch
        )?;
    }
    Ok(())
}

/// Write host-side sweep benchmarks as JSON (`BENCH_sweep.json`): one
/// entry per named sweep with wall time, aggregate cell host time,
/// executor throughput and the estimated speedup over a serial run.
/// Hand-rolled JSON, same as the trace exporter — the build is offline.
pub fn write_bench_json(path: &Path, sweeps: &[(String, SweepBench)]) -> Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"sweeps\": [")?;
    for (si, (name, b)) in sweeps.iter().enumerate() {
        writeln!(f, "    {{")?;
        writeln!(f, "      \"name\": \"{}\",", esc(name))?;
        writeln!(f, "      \"jobs\": {},", b.jobs)?;
        writeln!(f, "      \"wall_ns\": {},", b.wall_ns)?;
        writeln!(f, "      \"cells_host_ns\": {},", b.cells_host_ns())?;
        writeln!(f, "      \"events_run\": {},", b.events_run())?;
        writeln!(f, "      \"polls\": {},", b.polls())?;
        writeln!(f, "      \"events_per_sec\": {:.1},", b.events_per_sec())?;
        writeln!(
            f,
            "      \"speedup_vs_serial\": {:.3},",
            b.speedup_vs_serial()
        )?;
        writeln!(f, "      \"cells\": [")?;
        for (ci, c) in b.cells.iter().enumerate() {
            writeln!(
                f,
                "        {{\"label\": \"{}\", \"host_ns\": {}, \
                 \"events_run\": {}, \"polls\": {}}}{}",
                esc(&c.label),
                c.host_ns,
                c.events_run,
                c.polls,
                if ci + 1 < b.cells.len() { "," } else { "" }
            )?;
        }
        writeln!(f, "      ]")?;
        writeln!(
            f,
            "    }}{}",
            if si + 1 < sweeps.len() { "," } else { "" }
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}

/// Write points as CSV (one row per measurement).
pub fn write_csv(path: &Path, points: &[Point]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    writeln!(
        f,
        "matrix,algo,nodes,ranks,time_ns,max_internode_msgs,total_msgs,mean_send_nnz,dispatch"
    )?;
    for p in points {
        writeln!(
            f,
            "{},{},{},{},{},{},{},{:.2},{}",
            p.matrix, p.algo, p.nodes, p.ranks, p.time_ns, p.max_internode, p.total_msgs,
            p.mean_send_nnz, p.dispatch
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(matrix: &str, algo: &'static str, nodes: usize, t: u64, msgs: u64) -> Point {
        Point {
            matrix: matrix.into(),
            algo,
            nodes,
            ranks: nodes * 8,
            time_ns: t,
            max_internode: msgs,
            total_msgs: msgs * 10,
            mean_send_nnz: 3.0,
            dispatch: "personalized",
        }
    }

    #[test]
    fn renders_table_and_speedup() {
        let pts = vec![
            pt("m1", "personalized", 2, 1000, 50),
            pt("m1", "loc-nonblocking", 2, 100, 5),
        ];
        let s = render_figure("test fig", &pts);
        assert!(s.contains("m1"));
        assert!(s.contains("personalized"));
        assert!(s.contains("10.00x speedup"));
    }

    fn npt(method: &'static str, iters: usize, per_iter: f64) -> NeighborPoint {
        NeighborPoint {
            matrix: "m1".into(),
            method,
            flavor: "mvapich2",
            nodes: 2,
            ranks: 16,
            iters,
            setup_ns: 500,
            loop_ns: (per_iter * iters as f64) as u64,
            per_iter_ns: per_iter,
            internode_per_iter: 4.0,
            dispatch: "loc-nonblocking",
        }
    }

    #[test]
    fn renders_neighbor_table() {
        let pts = vec![
            npt("p2p", 16, 1000.0),
            npt("persistent", 16, 800.0),
            npt("loc-persistent", 16, 250.0),
        ];
        let s = render_neighbor_figure("neighbor fig", &pts);
        assert!(s.contains("m1"));
        assert!(s.contains("loc-persistent/iter"));
        assert!(s.contains("4.00x"));
    }

    #[test]
    fn neighbor_csv_has_all_rows() {
        let pts = vec![npt("p2p", 4, 100.0), npt("loc-persistent", 4, 50.0)];
        let path = std::env::temp_dir().join("sdde_neighbor_csv_test.csv");
        write_neighbor_csv(&path, &pts).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("matrix,method,mpi"));
        assert_eq!(content.lines().count(), 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bench_json_is_wellformed() {
        use crate::bench::par::CellBench;
        let b = SweepBench {
            jobs: 2,
            wall_ns: 500,
            cells: vec![CellBench {
                label: "m \"x\" nodes=2".into(),
                host_ns: 400,
                events_run: 7,
                polls: 9,
            }],
        };
        let path = std::env::temp_dir().join("sdde_bench_json_test.json");
        write_bench_json(&path, &[("fig7-quick".to_string(), b)]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("\"name\": \"fig7-quick\""));
        assert!(content.contains("\"jobs\": 2"));
        assert!(content.contains("\\\"x\\\""));
        assert_eq!(
            content.matches('{').count(),
            content.matches('}').count()
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_round_trip() {
        let pts = vec![pt("m", "rma", 4, 5, 2)];
        let path = std::env::temp_dir().join("sdde_csv_test.csv");
        write_csv(&path, &pts).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("matrix,algo"));
        assert!(content.contains("m,rma,4,32,5,2,20,3.00,personalized"));
        std::fs::remove_file(path).ok();
    }
}
