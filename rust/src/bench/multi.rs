//! Multi-pattern worlds: K concurrent SDDEs in ONE (possibly faulted)
//! world, each exchange on its own derived communicator.
//!
//! This is the harness the communicator-context refactor exists for. An
//! AMR-style application runs several sparse exchanges at once — one per
//! refinement level — and each must match only its own traffic even
//! though all K tag sequences start from the same base. The harness dups
//! a nested chain of communicators (ctx 1..=K; the world stays
//! `CtxId(0)`), drives all K SDDEs concurrently from every rank (they
//! interleave at await points, exactly like K outstanding collectives on
//! a real MPI rank), and digests each pattern's canonicalized result so
//! callers can compare against serial single-pattern oracles. Under
//! fault plans with duplicate delivery and deep unexpected queues, the
//! per-context trace rollup then proves send↔recv conservation *per
//! context* with zero cross-context deliveries.

use std::future::Future;
use std::hash::{Hash, Hasher};
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll};

use super::figures::Variant;
use super::runspec::watchdog_from_env;
use crate::mpi::World;
use crate::mpix::{
    alltoall_crs, alltoallv_crs, CrsResult, CrsvResult, IntraAlgo, MpixComm, MpixInfo,
    SddeAlgorithm,
};
use crate::simnet::{CostModel, FaultPlan, MpiFlavor, RegionKind, Time, Topology};
use crate::sparse::{MatrixPreset, Partition, SpmvPattern};
use crate::trace::{Trace, TraceConfig};
use crate::util::FxHasher;

/// Everything that parameterizes one multi-pattern run.
#[derive(Clone, Debug)]
pub struct MultiConfig {
    pub topo: Topology,
    pub flavor: MpiFlavor,
    pub algo: SddeAlgorithm,
    pub region: RegionKind,
    pub intra: IntraAlgo,
    pub variant: Variant,
    /// Number of concurrent SDDE patterns, each on its own communicator.
    pub patterns: usize,
    /// Matrix preset the per-pattern SpMV patterns are drawn from;
    /// pattern k uses seed `seed + k`, so the K exchanges differ.
    pub preset: MatrixPreset,
    pub seed: u64,
    pub faults: Option<FaultPlan>,
    pub trace: TraceConfig,
    pub watchdog: Option<Time>,
}

impl MultiConfig {
    pub fn new(topo: Topology, flavor: MpiFlavor, patterns: usize, preset: MatrixPreset) -> Self {
        MultiConfig {
            topo,
            flavor,
            algo: SddeAlgorithm::Dispatch,
            region: RegionKind::Node,
            intra: IntraAlgo::Personalized,
            variant: Variant::Variable,
            patterns,
            preset,
            seed: 2023,
            faults: None,
            trace: TraceConfig::counters_only(),
            watchdog: watchdog_from_env(),
        }
    }

    pub fn algo(mut self, algo: SddeAlgorithm) -> Self {
        self.algo = algo;
        self
    }

    pub fn variant(mut self, variant: Variant) -> Self {
        self.variant = variant;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn faults(mut self, faults: Option<FaultPlan>) -> Self {
        self.faults = faults;
        self
    }

    pub fn trace(mut self, trace: TraceConfig) -> Self {
        self.trace = trace;
        self
    }

    pub fn watchdog(mut self, horizon: Option<Time>) -> Self {
        self.watchdog = horizon;
        self
    }

    fn info(&self) -> MpixInfo {
        MpixInfo {
            algorithm: self.algo,
            region: self.region,
            intra: self.intra,
            ..MpixInfo::default()
        }
    }

    /// patterns[k][rank]: pattern k's send side at `rank`.
    fn build_patterns(&self) -> Rc<Vec<Vec<SpmvPattern>>> {
        let part = Partition::new(self.preset.n, self.topo.nranks());
        Rc::new(
            (0..self.patterns)
                .map(|k| {
                    (0..self.topo.nranks())
                        .map(|r| SpmvPattern::build(&self.preset, part, r, self.seed + k as u64))
                        .collect()
                })
                .collect(),
        )
    }

    fn build_world(&self, faults: Option<FaultPlan>) -> World {
        let mut b = World::builder(self.topo.clone(), CostModel::preset(self.flavor))
            .trace(self.trace)
            .faults(faults);
        if let Some(h) = self.watchdog {
            b = b.watchdog(h);
        }
        b.build()
    }
}

/// What one [`run_multi`] measured.
#[derive(Clone, Debug)]
pub struct MultiRun {
    /// Max per-rank virtual time across all K concurrent exchanges (ns).
    pub time_ns: Time,
    /// Trace of the whole world — its summary's per-context rollup is the
    /// conservation/cross-talk evidence.
    pub trace: Trace,
    /// `digests[k][rank]`: FxHash of pattern k's canonical result at
    /// `rank`; compare against [`oracle_digests`].
    pub digests: Vec<Vec<u64>>,
}

fn digest_crs(r: &CrsResult) -> u64 {
    let mut h = FxHasher::default();
    r.src.hash(&mut h);
    r.recvvals.hash(&mut h);
    h.finish()
}

fn digest_crsv(r: &CrsvResult) -> u64 {
    let mut h = FxHasher::default();
    r.src.hash(&mut h);
    r.recvcounts.hash(&mut h);
    r.recvvals.hash(&mut h);
    h.finish()
}

/// Poll a set of same-rank futures round-robin until all complete. The
/// executor is single-threaded, so "concurrent" means interleaved at
/// await points — K outstanding collectives on one rank, like an AMR
/// solver juggling one exchange per refinement level.
struct JoinAll<T> {
    futs: Vec<Pin<Box<dyn Future<Output = T>>>>,
    done: Vec<Option<T>>,
}

impl<T> JoinAll<T> {
    fn new(futs: Vec<Pin<Box<dyn Future<Output = T>>>>) -> JoinAll<T> {
        let n = futs.len();
        JoinAll {
            futs,
            done: (0..n).map(|_| None).collect(),
        }
    }
}

impl<T> Future for JoinAll<T> {
    type Output = Vec<T>;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Vec<T>> {
        let this = self.get_mut();
        let mut all = true;
        for i in 0..this.futs.len() {
            if this.done[i].is_none() {
                match this.futs[i].as_mut().poll(cx) {
                    Poll::Ready(v) => this.done[i] = Some(v),
                    Poll::Pending => all = false,
                }
            }
        }
        if all {
            Poll::Ready(this.done.iter_mut().map(|d| d.take().unwrap()).collect())
        } else {
            Poll::Pending
        }
    }
}

/// Run K concurrent SDDEs in one world. Every rank dups a nested chain of
/// K communicators off the world (contexts 1..=K; the chain also
/// exercises split-on-derived-comm), aligns on a world barrier, then
/// drives all K exchanges at once.
pub fn run_multi(cfg: &MultiConfig) -> MultiRun {
    assert!(cfg.patterns >= 1, "need at least one pattern");
    let patterns = cfg.build_patterns();
    let world = cfg.build_world(cfg.faults);
    let k = cfg.patterns;
    let (region, variant) = (cfg.region, cfg.variant);
    let cfg_info = cfg.info();
    let out = world.run(move |c| {
        let patterns = patterns.clone();
        let info = cfg_info.clone();
        async move {
            let mut comms = Vec::with_capacity(k);
            let mut parent = c.clone();
            for _ in 0..k {
                let next = parent.dup().await;
                comms.push(next.clone());
                parent = next;
            }
            c.barrier().await;
            let t0 = c.now();
            let rank = c.rank();
            let futs: Vec<Pin<Box<dyn Future<Output = u64>>>> = comms
                .into_iter()
                .enumerate()
                .map(|(i, comm)| {
                    let pats = patterns.clone();
                    let info = info.clone();
                    Box::pin(async move {
                        let mx = MpixComm::new(comm, region);
                        let pat = &pats[i][rank];
                        match variant {
                            Variant::ConstSize => {
                                let args = pat.crs_size_args();
                                digest_crs(&alltoall_crs(&mx, &info, &args).await.unwrap())
                            }
                            Variant::Variable => {
                                let args = pat.crsv_args();
                                digest_crsv(&alltoallv_crs(&mx, &info, &args).await.unwrap())
                            }
                        }
                    }) as Pin<Box<dyn Future<Output = u64>>>
                })
                .collect();
            let digests = JoinAll::new(futs).await;
            (c.now() - t0, digests)
        }
    });
    let time_ns = out.results.iter().map(|r| r.0).max().unwrap_or(0);
    let digests = (0..k)
        .map(|i| out.results.iter().map(|r| r.1[i]).collect())
        .collect();
    MultiRun {
        time_ns,
        trace: out.trace,
        digests,
    }
}

/// Serial single-pattern oracle: run each of the K patterns alone,
/// fault-free, on a fresh world's own communicator, and digest the
/// canonical results. Canonical SDDE results depend only on the pattern
/// — not on timing, faults, or which communicator carried them — so
/// [`run_multi`]'s digests must match these exactly.
pub fn oracle_digests(cfg: &MultiConfig) -> Vec<Vec<u64>> {
    let patterns = cfg.build_patterns();
    let (region, variant) = (cfg.region, cfg.variant);
    (0..cfg.patterns)
        .map(|i| {
            let world = cfg.build_world(None);
            let patterns = patterns.clone();
            let info = cfg.info();
            let out = world.run(move |c| {
                let patterns = patterns.clone();
                let info = info.clone();
                async move {
                    let mx = MpixComm::new(c.clone(), region);
                    let pat = &patterns[i][c.rank()];
                    c.barrier().await;
                    match variant {
                        Variant::ConstSize => {
                            let args = pat.crs_size_args();
                            digest_crs(&alltoall_crs(&mx, &info, &args).await.unwrap())
                        }
                        Variant::Variable => {
                            let args = pat.crsv_args();
                            digest_crsv(&alltoallv_crs(&mx, &info, &args).await.unwrap())
                        }
                    }
                }
            });
            out.results
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::FaultProfile;

    fn cfg(patterns: usize) -> MultiConfig {
        MultiConfig::new(
            Topology::quartz(2, 2),
            MpiFlavor::Mvapich2,
            patterns,
            MatrixPreset::cage14_like().scaled(200),
        )
        .algo(SddeAlgorithm::NonBlocking)
        .watchdog(None)
    }

    #[test]
    fn concurrent_patterns_agree_with_serial_oracles() {
        let c = cfg(2);
        let run = run_multi(&c);
        assert_eq!(run.digests, oracle_digests(&c));
        assert!(run.time_ns > 0);
        let s = &run.trace.summary;
        assert_eq!(s.cross_ctx_matches, 0);
        assert!(s.has_multiple_ctx());
        assert!(s.conservation_ok());
    }

    #[test]
    fn faults_move_time_not_results() {
        let base = cfg(2);
        let faulted = cfg(2).faults(Some(FaultPlan::with_profile(
            11,
            FaultProfile::heavy(),
        )));
        assert_eq!(run_multi(&faulted).digests, oracle_digests(&base));
    }
}
