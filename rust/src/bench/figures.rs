//! Node-count sweeps regenerating the paper's Figures 5–8.
//!
//! For each (matrix, node count, algorithm): build the per-rank SpMV
//! patterns once from the row-deterministic generator, run one simulated
//! SDDE, and record the maximum per-rank virtual time of the exchange
//! (all ranks enter together after a barrier) plus trace-derived traffic
//! metrics (the [`crate::trace`] rollup in counters-only mode). Every
//! point also records what the [`crate::mpix::dispatch`] layer would have
//! picked for that cell (the `dispatch` column) — the legacy heuristic by
//! default, the loaded evidence model when `SweepConfig::dispatch` is set.

use std::rc::Rc;

use super::par::{run_cells, timed, CellBench, Progress, ProgressSink, SweepBench};
use super::runspec::RunSpec;
use crate::mpix::dispatch;
use crate::mpix::{DispatchModel, IntraAlgo, PatternStats, SddeAlgorithm};
use crate::simnet::{FaultPlan, MpiFlavor, RegionKind, Time, Topology};
use crate::sparse::{MatrixPreset, Partition, SpmvPattern};
use crate::trace::{Trace, TraceConfig, TraceSummary};

/// Which SDDE API a figure exercises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// `MPIX_Alltoall_crs` — Figs. 5 & 6 (single-integer messages).
    ConstSize,
    /// `MPIX_Alltoallv_crs` — Figs. 7 & 8 (index-list messages).
    Variable,
}

/// Paper figure identifiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FigureId {
    Fig5,
    Fig6,
    Fig7,
    Fig8,
}

impl FigureId {
    pub fn parse(s: &str) -> Option<FigureId> {
        match s {
            "5" | "fig5" => Some(FigureId::Fig5),
            "6" | "fig6" => Some(FigureId::Fig6),
            "7" | "fig7" => Some(FigureId::Fig7),
            "8" | "fig8" => Some(FigureId::Fig8),
            _ => None,
        }
    }

    pub fn variant(&self) -> Variant {
        match self {
            FigureId::Fig5 | FigureId::Fig6 => Variant::ConstSize,
            FigureId::Fig7 | FigureId::Fig8 => Variant::Variable,
        }
    }

    pub fn flavor(&self) -> MpiFlavor {
        match self {
            FigureId::Fig5 | FigureId::Fig7 => MpiFlavor::Mvapich2,
            FigureId::Fig6 | FigureId::Fig8 => MpiFlavor::OpenMpi,
        }
    }

    pub fn title(&self) -> String {
        format!(
            "Figure {}: MPIX_Alltoall{}_crs methods using {}",
            match self {
                FigureId::Fig5 => 5,
                FigureId::Fig6 => 6,
                FigureId::Fig7 => 7,
                FigureId::Fig8 => 8,
            },
            if self.variant() == Variant::Variable {
                "v"
            } else {
                ""
            },
            self.flavor().name()
        )
    }
}

/// Sweep configuration (defaults mirror the paper's §V setup).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub variant: Variant,
    pub flavor: MpiFlavor,
    pub nodes: Vec<usize>,
    pub ppn: usize,
    pub matrices: Vec<MatrixPreset>,
    pub algos: Vec<SddeAlgorithm>,
    pub region: RegionKind,
    pub intra: IntraAlgo,
    pub seed: u64,
    pub progress: ProgressSink,
    /// Worker threads for the sweep (cells = matrix × node-count pairs).
    /// Results and output are identical for any value; see [`super::par`].
    pub jobs: usize,
    /// Seeded fault injection for every cell world (chaos sweeps). Each
    /// cell derives an independent child plan via [`FaultPlan::for_cell`],
    /// so results stay byte-identical for any `jobs` value. `None` (and
    /// the inactive plan) leave the sweep bit-identical to fault-free.
    pub faults: Option<FaultPlan>,
    /// Evidence model reported in the per-point `dispatch` column (and
    /// consulted when an algorithm under test is `Dispatch`). `None` =
    /// legacy heuristic.
    pub dispatch: Option<DispatchModel>,
    /// Noise regime handed to model-driven dispatch decisions.
    pub noise: Option<String>,
}

impl SweepConfig {
    /// Full paper setup for a figure: 2–64 nodes × 32 PPN, the four
    /// matrix analogs, all applicable algorithms.
    pub fn paper(fig: FigureId) -> SweepConfig {
        SweepConfig {
            variant: fig.variant(),
            flavor: fig.flavor(),
            nodes: vec![2, 4, 8, 16, 32, 64],
            ppn: 32,
            matrices: MatrixPreset::paper_set(),
            algos: match fig.variant() {
                Variant::ConstSize => SddeAlgorithm::ALL.to_vec(),
                Variant::Variable => SddeAlgorithm::VARIABLE.to_vec(),
            },
            region: RegionKind::Node,
            intra: IntraAlgo::Personalized,
            seed: 2023,
            progress: ProgressSink::Stderr,
            jobs: 1,
            faults: None,
            dispatch: None,
            noise: None,
        }
    }

    /// Scaled-down smoke configuration (CI / quick mode): matrices shrunk
    /// by `div`, small node counts and PPN.
    pub fn quick(fig: FigureId, div: usize) -> SweepConfig {
        let mut cfg = SweepConfig::paper(fig);
        cfg.nodes = vec![2, 4, 8];
        cfg.ppn = 8;
        cfg.matrices = cfg.matrices.iter().map(|m| m.scaled(div)).collect();
        cfg.progress = ProgressSink::Silent;
        cfg
    }
}

/// One measured point of a figure.
#[derive(Clone, Debug, PartialEq)]
pub struct Point {
    pub matrix: String,
    pub algo: &'static str,
    pub nodes: usize,
    pub ranks: usize,
    /// Max per-rank virtual time of the SDDE call (ns).
    pub time_ns: Time,
    /// Paper's red dots: max inter-node (user) messages sent by any rank.
    pub max_internode: u64,
    /// Total user messages across ranks (all tiers).
    pub total_msgs: u64,
    /// Mean per-rank destinations (send_nnz) — pattern statistic.
    pub mean_send_nnz: f64,
    /// What the dispatch layer picks for this cell's pattern regime (the
    /// heuristic, or the sweep's loaded model under `SweepConfig::noise`).
    pub dispatch: &'static str,
}

/// Aggregate [`PatternStats`] for a whole pattern set — the sweep-level
/// view of what one rank's [`PatternStats::measure`] sees inside an SDDE
/// call: mean destinations per rank, pooled local fraction.
pub fn pattern_set_stats(
    topo: &Topology,
    region: RegionKind,
    variant: Variant,
    patterns: &[SpmvPattern],
) -> PatternStats {
    let n = patterns.len().max(1);
    let mean_nnz =
        patterns.iter().map(|p| p.recv_nnz()).sum::<usize>() as f64 / n as f64;
    let (mut local, mut total) = (0usize, 0usize);
    for p in patterns {
        let me = topo.region_of(p.rank, region);
        local += p
            .needed
            .iter()
            .filter(|(o, _)| topo.region_of(*o, region) == me)
            .count();
        total += p.needed.len();
    }
    PatternStats {
        nranks: topo.nranks(),
        region_size: topo.region_size(0, region),
        send_nnz: mean_nnz.round() as usize,
        local_frac: if total == 0 {
            0.0
        } else {
            local as f64 / total as f64
        },
        constant: variant == Variant::ConstSize,
    }
}

/// Comm-local analog of [`pattern_set_stats`]: region membership comes
/// from the communicator's own (group-aware, densely re-indexed) region
/// map instead of raw machine topology, so it is meaningful on split and
/// dup'd communicators; `patterns` are indexed by comm-local rank. On the
/// world communicator this agrees with [`pattern_set_stats`] exactly.
pub fn pattern_set_stats_for(
    mx: &crate::mpix::MpixComm,
    variant: Variant,
    patterns: &[SpmvPattern],
) -> PatternStats {
    let n = patterns.len().max(1);
    let mean_nnz =
        patterns.iter().map(|p| p.recv_nnz()).sum::<usize>() as f64 / n as f64;
    let (mut local, mut total) = (0usize, 0usize);
    for p in patterns {
        let me = mx.region(p.rank);
        local += p
            .needed
            .iter()
            .filter(|(o, _)| mx.region(*o) == me)
            .count();
        total += p.needed.len();
    }
    PatternStats {
        nranks: mx.comm.nranks(),
        region_size: mx.region_ranks(0).len(),
        send_nnz: mean_nnz.round() as usize,
        local_frac: if total == 0 {
            0.0
        } else {
            local as f64 / total as f64
        },
        constant: variant == Variant::ConstSize,
    }
}

/// Run a sweep and return every measured point.
pub fn run_sweep(cfg: &SweepConfig) -> Vec<Point> {
    run_sweep_bench(cfg).0
}

/// Run a sweep, returning the points plus the host-side cost summary
/// (wall-clock, per-cell simulator time, executor throughput). The points
/// — and any Stderr/Collected progress output — are identical for every
/// `cfg.jobs` value; only the [`SweepBench`] changes.
pub fn run_sweep_bench(cfg: &SweepConfig) -> (Vec<Point>, SweepBench) {
    // One cell per (matrix, node count): the pattern build is shared by
    // the cell's algorithms, and cells are fully independent simulations.
    let keys: Vec<(usize, usize)> = cfg
        .matrices
        .iter()
        .enumerate()
        .flat_map(|(mi, _)| cfg.nodes.iter().map(move |&n| (mi, n)))
        .collect();
    let ((cell_out, _), wall_ns) = timed(|| {
        run_cells(cfg.jobs, keys.len(), cfg.progress, |i, pr| {
            let (mi, nodes) = keys[i];
            // Child plan per cell: derived from the cell *index*, not the
            // worker thread, so chaos sweeps are jobs-invariant.
            let faults = cfg.faults.map(|p| p.for_cell(i as u64));
            run_figure_cell(cfg, &cfg.matrices[mi], nodes, faults, pr)
        })
    });
    let mut points = Vec::new();
    let mut cells = Vec::new();
    for (pts, cell) in cell_out {
        points.extend(pts);
        cells.push(cell);
    }
    let bench = SweepBench {
        jobs: cfg.jobs.max(1),
        wall_ns,
        cells,
    };
    (points, bench)
}

/// One (matrix, node count) cell: build patterns once, run every
/// applicable algorithm, report points plus the cell's host cost.
fn run_figure_cell(
    cfg: &SweepConfig,
    preset: &MatrixPreset,
    nodes: usize,
    faults: Option<FaultPlan>,
    pr: &mut Progress,
) -> (Vec<Point>, CellBench) {
    let topo = Topology::quartz(nodes, cfg.ppn);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);
    pr.line(format!(
        "[sweep] {} nodes={nodes} ranks={nranks}: building patterns...",
        preset.name
    ));
    let patterns: Rc<Vec<SpmvPattern>> = Rc::new(
        (0..nranks)
            .map(|r| SpmvPattern::build(preset, part, r, cfg.seed))
            .collect(),
    );
    let mean_send_nnz =
        patterns.iter().map(|p| p.recv_nnz() as f64).sum::<f64>() / nranks as f64;
    // The dispatch column: one decision per cell, from the aggregate
    // pattern regime — reported even when sweeping explicit algorithms.
    let stats = pattern_set_stats(&topo, cfg.region, cfg.variant, &patterns);
    let pick =
        dispatch::select(cfg.dispatch.as_ref(), &stats, cfg.noise.as_deref());
    let spec = RunSpec::new(topo, cfg.flavor)
        .region(cfg.region)
        .intra(cfg.intra)
        .seed(cfg.seed)
        .faults(faults)
        .dispatch(cfg.dispatch.clone())
        .noise(cfg.noise.clone());
    let mut points = Vec::new();
    let mut cell = CellBench {
        label: format!("{} nodes={nodes}", preset.name),
        host_ns: 0,
        events_run: 0,
        polls: 0,
    };
    for &algo in &cfg.algos {
        if cfg.variant == Variant::Variable && algo == SddeAlgorithm::Rma {
            continue;
        }
        let run = spec
            .clone()
            .algo(algo)
            .run_sdde(cfg.variant, patterns.clone());
        cell.host_ns += run.stats.host_ns;
        cell.events_run += run.stats.events_run;
        cell.polls += run.stats.polls;
        pr.line(format!(
            "[sweep]   {:>17}: {:>12}  max-internode={}",
            algo.name(),
            crate::util::fmt::ns(run.time_ns),
            run.summary().max_internode_per_rank()
        ));
        points.push(Point {
            matrix: preset.name.clone(),
            algo: algo.name(),
            nodes,
            ranks: nranks,
            time_ns: run.time_ns,
            max_internode: run.summary().max_internode_per_rank(),
            total_msgs: run.summary().total_user_msgs(),
            mean_send_nnz,
            dispatch: pick.algo.name(),
        });
    }
    (points, cell)
}

/// Run one SDDE on a fresh world; returns (max per-rank elapsed, trace
/// rollup). Thin wrapper over [`RunSpec::run_sdde`] kept for external
/// callers (ablations, conservation tests); sweeps build specs directly.
pub fn run_once(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    intra: IntraAlgo,
    variant: Variant,
    patterns: Rc<Vec<SpmvPattern>>,
) -> (Time, TraceSummary) {
    let run = RunSpec::new(topo, flavor)
        .algo(algo)
        .region(region)
        .intra(intra)
        .run_sdde(variant, patterns);
    (run.time_ns, run.trace.summary)
}

/// Like [`run_once`] but with full event recording: returns the complete
/// [`Trace`] for export / critical-path analysis (the `sdde trace` path).
pub fn run_once_traced(
    topo: Topology,
    flavor: MpiFlavor,
    algo: SddeAlgorithm,
    region: RegionKind,
    intra: IntraAlgo,
    variant: Variant,
    patterns: Rc<Vec<SpmvPattern>>,
) -> (Time, Trace) {
    let run = RunSpec::new(topo, flavor)
        .algo(algo)
        .region(region)
        .intra(intra)
        .trace(TraceConfig::full())
        .run_sdde(variant, patterns);
    (run.time_ns, run.trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_produces_points() {
        let mut cfg = SweepConfig::quick(FigureId::Fig7, 400);
        cfg.nodes = vec![2, 4];
        cfg.matrices.truncate(2);
        let pts = run_sweep(&cfg);
        // 2 matrices × 2 node counts × 4 variable algorithms
        assert_eq!(pts.len(), 2 * 2 * 4);
        for p in &pts {
            assert!(p.time_ns > 0, "{p:?}");
            // No model loaded: the dispatch column is the heuristic pick,
            // and small sparse worlds resolve to Personalized.
            assert_eq!(p.dispatch, "personalized", "{p:?}");
        }
    }

    #[test]
    fn aggregation_reduces_internode_messages() {
        // The defining effect of the paper: locality-aware variants send
        // fewer inter-node messages than the standard ones.
        let mut cfg = SweepConfig::quick(FigureId::Fig7, 200);
        cfg.nodes = vec![4];
        cfg.matrices = vec![MatrixPreset::cage14_like().scaled(200)];
        let pts = run_sweep(&cfg);
        let get = |name: &str| {
            pts.iter()
                .find(|p| p.algo == name)
                .map(|p| p.max_internode)
                .unwrap()
        };
        let std = get("personalized").min(get("nonblocking"));
        let agg = get("loc-personalized").max(get("loc-nonblocking"));
        assert!(
            agg < std,
            "aggregated {agg} not below standard {std}"
        );
    }

    #[test]
    fn sweep_bench_reports_host_cost() {
        let mut cfg = SweepConfig::quick(FigureId::Fig5, 400);
        cfg.nodes = vec![2];
        cfg.matrices.truncate(1);
        let (pts, bench) = run_sweep_bench(&cfg);
        assert!(!pts.is_empty());
        assert_eq!(bench.jobs, 1);
        assert_eq!(bench.cells.len(), 1);
        assert!(bench.cells_host_ns() > 0);
        assert!(bench.events_run() > 0);
        // Serial: simulator host time is a subset of the sweep wall time.
        assert!(bench.wall_ns >= bench.cells_host_ns());
        assert!(bench.speedup_vs_serial() <= 1.0 + 1e-9);
    }

    #[test]
    fn off_fault_plan_sweep_is_identical() {
        // FaultPlan::off() bit-identity at the sweep level: every point
        // (times included) must match the no-plan sweep exactly.
        let mut cfg = SweepConfig::quick(FigureId::Fig5, 400);
        cfg.nodes = vec![2];
        cfg.matrices.truncate(1);
        let base = run_sweep(&cfg);
        cfg.faults = Some(FaultPlan::off());
        let off = run_sweep(&cfg);
        assert_eq!(base, off);
    }

    #[test]
    fn faulted_sweep_is_jobs_invariant_and_traffic_preserving() {
        let mut cfg = SweepConfig::quick(FigureId::Fig5, 400);
        cfg.nodes = vec![2, 4];
        cfg.matrices.truncate(2);
        let base = run_sweep(&cfg);
        cfg.faults = Some(FaultPlan::seeded(42));
        let serial = run_sweep(&cfg);
        cfg.jobs = 3;
        let par = run_sweep(&cfg);
        // Per-cell plans derive from the cell index, so worker assignment
        // can't matter (invariant 7 with faults on).
        assert_eq!(serial, par);
        // Faults perturb timing, never traffic (counted at injection).
        assert_eq!(base.len(), serial.len());
        for (b, f) in base.iter().zip(&serial) {
            assert_eq!(b.max_internode, f.max_internode, "{}", b.algo);
            assert_eq!(b.total_msgs, f.total_msgs, "{}", b.algo);
        }
    }

    #[test]
    fn model_changes_the_dispatch_column_not_the_points() {
        // Loading a model re-labels the dispatch column; the measured
        // points for explicit algorithms are untouched.
        let mut cfg = SweepConfig::quick(FigureId::Fig5, 400);
        cfg.nodes = vec![2];
        cfg.matrices.truncate(1);
        let base = run_sweep(&cfg);
        cfg.dispatch = Some(crate::mpix::DispatchModel::embedded().clone());
        let modeled = run_sweep(&cfg);
        assert_eq!(base.len(), modeled.len());
        for (b, m) in base.iter().zip(&modeled) {
            assert_eq!(b.time_ns, m.time_ns, "{}", b.algo);
            assert_eq!(b.max_internode, m.max_internode, "{}", b.algo);
            // Both columns carry *some* valid pick.
            assert!(SddeAlgorithm::parse(b.dispatch).is_ok());
            assert!(SddeAlgorithm::parse(m.dispatch).is_ok());
        }
    }

    #[test]
    fn figure_ids_map_correctly() {
        assert_eq!(FigureId::Fig5.variant(), Variant::ConstSize);
        assert_eq!(FigureId::Fig8.variant(), Variant::Variable);
        assert_eq!(FigureId::Fig7.flavor(), MpiFlavor::Mvapich2);
        assert_eq!(FigureId::Fig6.flavor(), MpiFlavor::OpenMpi);
        assert!(FigureId::parse("7") == Some(FigureId::Fig7));
    }
}
