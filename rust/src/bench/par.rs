//! Parallel sweep engine: runs independent simulation cells on a scoped
//! thread pool with results and progress output collected *in cell index
//! order*, so a parallel sweep is byte-identical to a serial one.
//!
//! A "cell" is one independent unit of a sweep — e.g. one (matrix, node
//! count) pair of a figure sweep. Each cell builds its own [`crate::mpi::World`]
//! inside its worker thread; the simulator itself stays single-threaded
//! and `!Send`, only the *configs* cross threads. Virtual times are a pure
//! function of the cell inputs, so the jobs count can never change a
//! result — only wall-clock time (determinism invariant: jobs=N output ==
//! jobs=1 output, bit for bit; enforced by `tests/par_determinism.rs`).
//!
//! Progress lines are buffered per cell and flushed in index order as the
//! completed prefix grows, so interleaved workers never interleave output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Where a sweep's per-cell progress lines go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgressSink {
    /// Stream to stderr (in cell order, even when cells run in parallel).
    Stderr,
    /// Drop all progress output.
    Silent,
    /// Collect into the `Vec<String>` returned by [`run_cells`]
    /// (in cell order) — used by tests and embedding callers.
    Collected,
}

/// Per-cell progress handle. Workers write through this instead of
/// `eprintln!` so the engine can buffer and order the output.
pub struct Progress {
    mode: ProgressMode,
}

enum ProgressMode {
    /// Serial + Stderr: stream directly, nothing to reorder.
    Direct,
    /// Nothing is kept.
    Drop,
    /// Buffer for ordered flushing (parallel, or serial Collected).
    Buffer(Vec<String>),
}

impl Progress {
    /// Emit one progress line (a full line, no trailing newline).
    pub fn line(&mut self, s: String) {
        match &mut self.mode {
            ProgressMode::Direct => eprintln!("{s}"),
            ProgressMode::Drop => {}
            ProgressMode::Buffer(v) => v.push(s),
        }
    }

    fn into_lines(self) -> Vec<String> {
        match self.mode {
            ProgressMode::Buffer(v) => v,
            _ => Vec::new(),
        }
    }
}

/// Resolve the worker count: explicit CLI value wins, then the
/// `SDDE_JOBS` environment variable, then serial (1).
pub fn resolve_jobs(cli: Option<usize>) -> usize {
    cli.or_else(|| {
        std::env::var("SDDE_JOBS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
    })
    .unwrap_or(1)
    .max(1)
}

/// Ordered-flush state shared by the workers: `pending[i]` holds cell i's
/// buffered lines once it finishes; whoever completes a cell drains the
/// contiguous done-prefix starting at `next`.
struct FlushState {
    next: usize,
    pending: Vec<Option<Vec<String>>>,
    collected: Vec<String>,
}

impl FlushState {
    fn flush_ready(&mut self, sink: ProgressSink) {
        while self.next < self.pending.len() {
            let Some(lines) = self.pending[self.next].take() else {
                break;
            };
            for l in lines {
                match sink {
                    ProgressSink::Stderr => eprintln!("{l}"),
                    ProgressSink::Silent => {}
                    ProgressSink::Collected => self.collected.push(l),
                }
            }
            self.next += 1;
        }
    }
}

/// Run `n` independent cells with up to `jobs` worker threads and return
/// `(results in cell order, collected progress lines in cell order)`.
///
/// `jobs <= 1` runs everything on the calling thread with zero overhead
/// (and streams Stderr progress unbuffered) — the serial reference path.
/// Parallel workers pull cell indices from a shared work queue (dynamic
/// load balancing: cells can differ in cost by orders of magnitude across
/// node counts), park each result in its own slot, and flush progress in
/// index order, so both return values are independent of `jobs`.
pub fn run_cells<T, F>(jobs: usize, n: usize, sink: ProgressSink, f: F) -> (Vec<T>, Vec<String>)
where
    T: Send,
    F: Fn(usize, &mut Progress) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        let mut results = Vec::with_capacity(n);
        let mut collected = Vec::new();
        for i in 0..n {
            let mut p = Progress {
                mode: match sink {
                    ProgressSink::Stderr => ProgressMode::Direct,
                    ProgressSink::Silent => ProgressMode::Drop,
                    ProgressSink::Collected => ProgressMode::Buffer(Vec::new()),
                },
            };
            results.push(f(i, &mut p));
            collected.extend(p.into_lines());
        }
        return (results, collected);
    }

    let next_cell = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let flush = Mutex::new(FlushState {
        next: 0,
        pending: (0..n).map(|_| None).collect(),
        collected: Vec::new(),
    });

    std::thread::scope(|scope| {
        for _ in 0..jobs.min(n) {
            scope.spawn(|| loop {
                let i = next_cell.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut p = Progress {
                    mode: match sink {
                        ProgressSink::Silent => ProgressMode::Drop,
                        _ => ProgressMode::Buffer(Vec::new()),
                    },
                };
                let r = f(i, &mut p);
                *slots[i].lock().unwrap() = Some(r);
                let mut fl = flush.lock().unwrap();
                fl.pending[i] = Some(p.into_lines());
                fl.flush_ready(sink);
            });
        }
    });

    let results = slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("parallel sweep cell produced no result")
        })
        .collect();
    (results, flush.into_inner().unwrap().collected)
}

/// Host-side cost of one sweep cell (wall-clock of the simulator runs it
/// contains — *not* virtual time, which is unaffected by any of this).
#[derive(Clone, Debug)]
pub struct CellBench {
    pub label: String,
    /// Host nanoseconds spent inside `Sim::run` for this cell.
    pub host_ns: u64,
    pub events_run: u64,
    pub polls: u64,
}

/// Host-side summary of a whole sweep: wall-clock with `jobs` workers vs
/// the serial-equivalent cost (the sum of per-cell host time).
#[derive(Clone, Debug)]
pub struct SweepBench {
    pub jobs: usize,
    /// Wall-clock of the whole sweep, including pattern building.
    pub wall_ns: u64,
    pub cells: Vec<CellBench>,
}

impl SweepBench {
    /// Sum of per-cell simulator host time — what a serial run would spend
    /// inside `Sim::run` (pattern building excluded on both sides).
    pub fn cells_host_ns(&self) -> u64 {
        self.cells.iter().map(|c| c.host_ns).sum()
    }

    pub fn events_run(&self) -> u64 {
        self.cells.iter().map(|c| c.events_run).sum()
    }

    pub fn polls(&self) -> u64 {
        self.cells.iter().map(|c| c.polls).sum()
    }

    /// Aggregate executor throughput: simulated events per wall second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events_run() as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Estimated speedup over a serial run: summed per-cell simulator host
    /// time over observed wall time. (A lower bound when pattern building
    /// is significant, since that also parallelizes but isn't counted in
    /// `cells_host_ns`.)
    pub fn speedup_vs_serial(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.cells_host_ns() as f64 / self.wall_ns as f64
    }

    /// One-paragraph human summary for stderr.
    pub fn render(&self, name: &str) -> String {
        format!(
            "[bench] {name}: jobs={} wall={:.3}s cells-host={:.3}s \
             events={} ({:.2}M events/s) speedup-vs-serial={:.2}x",
            self.jobs,
            self.wall_ns as f64 / 1e9,
            self.cells_host_ns() as f64 / 1e9,
            self.events_run(),
            self.events_per_sec() / 1e6,
            self.speedup_vs_serial(),
        )
    }
}

/// Measure wall-clock around a closure (helper for `run_*_bench`).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_nanos() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree() {
        let work = |i: usize, p: &mut Progress| {
            // Uneven per-cell cost exercises the dynamic queue.
            let mut acc = 0u64;
            for k in 0..(1 + i % 7) * 10_000 {
                acc = acc.wrapping_mul(31).wrapping_add(k as u64);
            }
            p.line(format!("cell {i} start"));
            p.line(format!("cell {i} acc={acc}"));
            (i, acc)
        };
        let (serial, s_lines) = run_cells(1, 23, ProgressSink::Collected, work);
        for jobs in [2, 4, 16] {
            let (par, p_lines) = run_cells(jobs, 23, ProgressSink::Collected, work);
            assert_eq!(serial, par, "results differ at jobs={jobs}");
            assert_eq!(s_lines, p_lines, "progress lines differ at jobs={jobs}");
        }
        assert_eq!(s_lines.len(), 46);
        assert!(s_lines[0].starts_with("cell 0 "));
        assert!(s_lines[45].starts_with("cell 22 "));
    }

    #[test]
    fn silent_collects_nothing() {
        let (res, lines) = run_cells(4, 8, ProgressSink::Silent, |i, p| {
            p.line(format!("noise {i}"));
            i * 2
        });
        assert_eq!(res, (0..8).map(|i| i * 2).collect::<Vec<_>>());
        assert!(lines.is_empty());
    }

    #[test]
    fn zero_and_one_cells() {
        let (res, lines) = run_cells::<usize, _>(4, 0, ProgressSink::Collected, |_, _| {
            unreachable!()
        });
        assert!(res.is_empty() && lines.is_empty());
        let (res, _) = run_cells(8, 1, ProgressSink::Collected, |i, _| i + 41);
        assert_eq!(res, vec![41]);
    }

    #[test]
    fn more_jobs_than_cells() {
        let (res, _) = run_cells(64, 3, ProgressSink::Silent, |i, _| i);
        assert_eq!(res, vec![0, 1, 2]);
    }

    #[test]
    fn resolve_jobs_precedence() {
        // CLI beats everything; explicit 0 clamps to 1. (The env-var path
        // is covered implicitly — tests must not mutate process env.)
        assert_eq!(resolve_jobs(Some(7)), 7);
        assert_eq!(resolve_jobs(Some(0)), 1);
        assert!(resolve_jobs(None) >= 1);
    }

    #[test]
    fn sweep_bench_math() {
        let b = SweepBench {
            jobs: 4,
            wall_ns: 1_000_000_000,
            cells: vec![
                CellBench {
                    label: "a".into(),
                    host_ns: 1_500_000_000,
                    events_run: 2_000_000,
                    polls: 10,
                },
                CellBench {
                    label: "b".into(),
                    host_ns: 1_500_000_000,
                    events_run: 1_000_000,
                    polls: 20,
                },
            ],
        };
        assert_eq!(b.cells_host_ns(), 3_000_000_000);
        assert_eq!(b.events_run(), 3_000_000);
        assert_eq!(b.polls(), 30);
        assert!((b.speedup_vs_serial() - 3.0).abs() < 1e-9);
        assert!((b.events_per_sec() - 3e6).abs() < 1.0);
        let s = b.render("quick-fig7");
        assert!(s.contains("jobs=4"));
        assert!(s.contains("3.00x"));
    }
}
