//! Model calibration: turn figure + chaos sweeps into a
//! [`DispatchModel`] — the `sdde calibrate` engine.
//!
//! Three evidence passes feed the model's per-(bucket, algorithm) rows:
//!
//! 1. **Base cost** — fault-free figure sweeps; each cell's times are
//!    normalized to the cell's winner, then averaged per bucket, so
//!    `base = 1.0` marks the fault-free pick and other algorithms carry
//!    their relative slowdown.
//! 2. **Fault inflation** — the same sweeps re-run per (profile, seed)
//!    chaos-style; `inflation = faulted time / baseline time`, averaged
//!    per (bucket, algorithm, profile). This is the robustness evidence
//!    the scoring rule `base × (1 + w·(inflation−1))` weighs.
//! 3. **Critical-path wait share** — one fully-traced run per (bucket,
//!    algorithm) on the bucket's first cell;
//!    [`critical_path`] attributes chain time to event kinds, and the
//!    `wait / covered` share becomes the model's `cp_wait` tiebreaker
//!    (ties in score go to the algorithm that idles least).
//!
//! All accumulation is over `BTreeMap`s and every sweep is
//! jobs-invariant, so calibration output is byte-identical for any
//! `jobs` value — the same determinism contract as the sweeps it rides.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::figures::{run_sweep, FigureId, SweepConfig, Variant};
use super::par::ProgressSink;
use super::runspec::RunSpec;
use crate::mpix::{DispatchModel, ModelEntry, PatternStats, SddeAlgorithm};
use crate::simnet::{FaultPlan, FaultProfile, Topology};
use crate::sparse::{MatrixPreset, Partition, SpmvPattern};
use crate::trace::{critical_path, EventKind, TraceConfig};

/// What to calibrate over. Defaults ([`CalibrateConfig::quick`]) are CI
/// sized; `sdde calibrate` exposes every axis.
#[derive(Clone, Debug)]
pub struct CalibrateConfig {
    /// Figures to sweep (their variants decide which buckets get rows).
    pub figs: Vec<FigureId>,
    /// Matrix shrink factor for the stock paper set.
    pub div: usize,
    pub nodes: Vec<usize>,
    pub ppn: usize,
    /// Explicit matrix set; `None` = the paper set scaled by `div`.
    pub matrices: Option<Vec<MatrixPreset>>,
    /// Fault profiles to calibrate inflation under (stock names).
    pub profiles: Vec<String>,
    /// Fault-plan seeds per profile (means over seeds).
    pub seeds: Vec<u64>,
    /// Robustness weight stored in the model.
    pub robustness: f64,
    pub jobs: usize,
    pub progress: ProgressSink,
}

impl CalibrateConfig {
    pub fn quick() -> CalibrateConfig {
        CalibrateConfig {
            figs: vec![FigureId::Fig5, FigureId::Fig7],
            div: 400,
            nodes: vec![2, 4],
            ppn: 4,
            matrices: None,
            profiles: ["light", "heavy", "jitter", "straggler"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            seeds: vec![1, 2],
            robustness: 1.0,
            jobs: 1,
            progress: ProgressSink::Silent,
        }
    }
}

/// Mean-accumulator keyed for deterministic iteration.
type Acc<K> = BTreeMap<K, (f64, usize)>;

fn push<K: Ord>(acc: &mut Acc<K>, key: K, v: f64) {
    let e = acc.entry(key).or_insert((0.0, 0));
    e.0 += v;
    e.1 += 1;
}

fn mean(e: &(f64, usize)) -> f64 {
    e.0 / e.1.max(1) as f64
}

/// The bucket a sweep point's cell falls into: the same discretization
/// [`PatternStats::measure`] feeds at dispatch time, built from the
/// cell's aggregate regime (mean destinations, node-region size = PPN).
fn point_bucket(ranks: usize, ppn: usize, mean_send_nnz: f64, variant: Variant) -> String {
    PatternStats {
        nranks: ranks,
        region_size: ppn,
        send_nnz: mean_send_nnz.round() as usize,
        local_frac: 0.0,
        constant: variant == Variant::ConstSize,
    }
    .bucket()
}

fn sweep_for(cfg: &CalibrateConfig, fig: FigureId) -> SweepConfig {
    let mut sweep = SweepConfig::quick(fig, cfg.div);
    sweep.nodes = cfg.nodes.clone();
    sweep.ppn = cfg.ppn;
    if let Some(m) = &cfg.matrices {
        sweep.matrices = m.clone();
    }
    sweep.jobs = cfg.jobs;
    sweep.progress = cfg.progress;
    sweep
}

/// Run the calibration sweeps and distill a [`DispatchModel`].
pub fn run_calibrate(cfg: &CalibrateConfig) -> Result<DispatchModel> {
    if cfg.figs.is_empty() {
        return Err(anyhow!("calibrate needs at least one figure"));
    }
    let profiles: Vec<(String, FaultProfile)> = cfg
        .profiles
        .iter()
        .map(|name| {
            FaultProfile::parse(name)
                .map(|p| (name.clone(), p))
                .map_err(|e| anyhow!("bad calibration profile '{name}': {e}"))
        })
        .collect::<Result<_>>()?;

    let mut base_acc: Acc<(String, &'static str)> = BTreeMap::new();
    let mut infl_acc: Acc<(String, &'static str, String)> = BTreeMap::new();
    let mut cp_acc: Acc<(String, &'static str)> = BTreeMap::new();

    for &fig in &cfg.figs {
        let sweep = sweep_for(cfg, fig);
        let baseline = run_sweep(&sweep);

        // Pass 1: per-cell normalized base cost, pooled per bucket.
        let mut cell_best: BTreeMap<(String, usize), u64> = BTreeMap::new();
        for p in &baseline {
            let k = (p.matrix.clone(), p.nodes);
            let best = cell_best.entry(k).or_insert(u64::MAX);
            *best = (*best).min(p.time_ns);
        }
        for p in &baseline {
            let best = cell_best[&(p.matrix.clone(), p.nodes)].max(1);
            let bucket = point_bucket(p.ranks, sweep.ppn, p.mean_send_nnz, sweep.variant);
            push(&mut base_acc, (bucket, p.algo), p.time_ns as f64 / best as f64);
        }

        // Pass 2: fault inflation per (bucket, algorithm, profile).
        for (name, profile) in &profiles {
            for &seed in &cfg.seeds {
                let mut faulted = sweep.clone();
                faulted.faults = Some(FaultPlan::with_profile(seed, *profile));
                let points = run_sweep(&faulted);
                for (b, f) in baseline.iter().zip(&points) {
                    debug_assert_eq!((b.algo, b.nodes), (f.algo, f.nodes));
                    let bucket =
                        point_bucket(b.ranks, sweep.ppn, b.mean_send_nnz, sweep.variant);
                    push(
                        &mut infl_acc,
                        (bucket, b.algo, name.clone()),
                        f.time_ns as f64 / b.time_ns.max(1) as f64,
                    );
                }
            }
        }

        // Pass 3: critical-path wait share on each bucket's first cell.
        let mut seen: BTreeMap<String, (String, usize)> = BTreeMap::new();
        for p in &baseline {
            let bucket = point_bucket(p.ranks, sweep.ppn, p.mean_send_nnz, sweep.variant);
            seen.entry(bucket)
                .or_insert_with(|| (p.matrix.clone(), p.nodes));
        }
        for (bucket, (matrix, nodes)) in &seen {
            let preset = sweep
                .matrices
                .iter()
                .find(|m| &m.name == matrix)
                .expect("cell matrix came from this sweep");
            let topo = Topology::quartz(*nodes, sweep.ppn);
            let nranks = topo.nranks();
            let part = Partition::new(preset.n, nranks);
            let patterns: Rc<Vec<SpmvPattern>> = Rc::new(
                (0..nranks)
                    .map(|r| SpmvPattern::build(preset, part, r, sweep.seed))
                    .collect(),
            );
            for &algo in &sweep.algos {
                if sweep.variant == Variant::Variable && algo == SddeAlgorithm::Rma {
                    continue;
                }
                let run = RunSpec::new(topo.clone(), sweep.flavor)
                    .algo(algo)
                    .region(sweep.region)
                    .intra(sweep.intra)
                    .trace(TraceConfig::full())
                    .run_sdde(sweep.variant, patterns.clone());
                let cp = critical_path(&run.trace.events);
                let wait = cp
                    .by_kind
                    .iter()
                    .find(|(k, _)| *k == EventKind::Wait)
                    .map(|&(_, t)| t)
                    .unwrap_or(0);
                push(
                    &mut cp_acc,
                    (bucket.clone(), algo.name()),
                    wait as f64 / cp.covered_ns.max(1) as f64,
                );
            }
        }
    }

    // Distill: one entry per (bucket, algorithm), in bucket order with
    // algorithms in their canonical rank.
    let mut entries: Vec<ModelEntry> = base_acc
        .iter()
        .map(|((bucket, algo_name), acc)| {
            let algo = SddeAlgorithm::parse(algo_name)
                .expect("accumulator keys are canonical names");
            let inflation = profiles
                .iter()
                .map(|(name, _)| {
                    let v = infl_acc
                        .get(&(bucket.clone(), *algo_name, name.clone()))
                        .map(mean)
                        .unwrap_or(1.0);
                    (name.clone(), v)
                })
                .collect();
            ModelEntry {
                bucket: bucket.clone(),
                algo,
                base: mean(acc),
                cp_wait: cp_acc
                    .get(&(bucket.clone(), *algo_name))
                    .map(mean)
                    .unwrap_or(0.0),
                inflation,
            }
        })
        .collect();
    let rank = |a: SddeAlgorithm| {
        SddeAlgorithm::CONST_SIZE
            .iter()
            .position(|&x| x == a)
            .unwrap_or(usize::MAX)
    };
    entries.sort_by(|a, b| a.bucket.cmp(&b.bucket).then(rank(a.algo).cmp(&rank(b.algo))));

    Ok(DispatchModel {
        robustness: cfg.robustness,
        profiles: cfg.profiles.clone(),
        entries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CalibrateConfig {
        CalibrateConfig {
            figs: vec![FigureId::Fig5],
            div: 400,
            nodes: vec![2],
            ppn: 4,
            matrices: Some(vec![MatrixPreset::cage14_like().scaled(400)]),
            profiles: vec!["heavy".to_string()],
            seeds: vec![1],
            robustness: 1.0,
            jobs: 1,
            progress: ProgressSink::Silent,
        }
    }

    #[test]
    fn calibrate_builds_a_coherent_model() {
        let model = run_calibrate(&tiny()).unwrap();
        assert_eq!(model.profiles, vec!["heavy"]);
        // One bucket (one cell), every const-size algorithm measured.
        assert_eq!(model.entries.len(), SddeAlgorithm::CONST_SIZE.len());
        let mut best = f64::MAX;
        for e in &model.entries {
            assert!(e.base >= 1.0 - 1e-12, "{e:?}");
            best = best.min(e.base);
            assert_eq!(e.inflation.len(), 1);
            assert_eq!(e.inflation[0].0, "heavy");
            assert!(e.inflation[0].1 > 0.0, "{e:?}");
            assert!((0.0..=1.0).contains(&e.cp_wait), "{e:?}");
        }
        // Normalization: the fault-free winner sits at exactly 1.0.
        assert!((best - 1.0).abs() < 1e-12);
        // The model must select *something* for its own bucket.
        let bucket = &model.entries[0].bucket;
        assert!(model.buckets().contains(bucket));
    }

    #[test]
    fn calibrated_model_round_trips_through_json() {
        let model = run_calibrate(&tiny()).unwrap();
        let reparsed = DispatchModel::from_json(&model.to_json()).unwrap();
        assert_eq!(reparsed, model);
    }

    #[test]
    fn calibration_is_jobs_invariant() {
        let mut cfg = tiny();
        let serial = run_calibrate(&cfg).unwrap();
        cfg.jobs = 3;
        let parallel = run_calibrate(&cfg).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn unknown_profile_is_rejected_loudly() {
        let mut cfg = tiny();
        cfg.profiles = vec!["gremlins".to_string()];
        let err = run_calibrate(&cfg).unwrap_err().to_string();
        assert!(err.contains("gremlins"), "{err}");
    }
}
