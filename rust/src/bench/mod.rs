//! Figure-regeneration harness: sweeps node counts × matrices × algorithms
//! × MPI flavors and reports the virtual SDDE time plus the paper's
//! red-dot metric (max inter-node messages per rank). One [`figures`]
//! sweep per paper figure (5–8); [`report`] renders tables/CSV.

pub mod figures;
pub mod report;

pub use figures::{run_sweep, FigureId, Point, SweepConfig, Variant};
pub use report::{render_figure, write_csv};
