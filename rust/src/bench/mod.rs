//! Figure-regeneration harness: sweeps node counts × matrices × algorithms
//! × MPI flavors and reports the virtual SDDE time plus the paper's
//! red-dot metric (max inter-node messages per rank). [`runspec`] is the
//! single builder every harness run goes through (pattern × algorithm ×
//! faults × trace × dispatch model); one [`figures`] sweep per paper
//! figure (5–8); [`neighbor`] sweeps the steady-state persistent
//! neighborhood collectives; [`report`] renders tables/CSV; [`par`] runs
//! independent sweep cells on worker threads with bit-identical results
//! and ordered progress output; [`chaos`] re-runs a figure sweep under
//! seeded fault plans and reports makespan inflation; [`calibrate`]
//! distills figure + chaos sweeps into a [`crate::mpix::DispatchModel`];
//! [`multi`] drives K concurrent SDDEs in one faulted world, one derived
//! communicator per pattern, and checks them against serial oracles.

pub mod calibrate;
pub mod chaos;
pub mod figures;
pub mod multi;
pub mod neighbor;
pub mod par;
pub mod report;
pub mod runspec;

pub use calibrate::{run_calibrate, CalibrateConfig};
pub use chaos::{profile_label, run_chaos, ChaosConfig, ChaosReport, ChaosRun};
pub use figures::{
    pattern_set_stats, pattern_set_stats_for, run_once, run_once_traced, run_sweep,
    run_sweep_bench, FigureId, Point, SweepConfig, Variant,
};
pub use multi::{oracle_digests, run_multi, MultiConfig, MultiRun};
pub use neighbor::{
    run_halo_once, run_neighbor_sweep, run_neighbor_sweep_bench, HaloMethod, NeighborPoint,
    NeighborSweepConfig,
};
pub use par::{
    resolve_jobs, run_cells, CellBench, Progress, ProgressSink, SweepBench,
};
pub use report::{
    render_figure, render_neighbor_figure, write_bench_json, write_csv, write_neighbor_csv,
};
pub use runspec::{HaloRun, RunSpec, SddeRun};
