//! Figure-regeneration harness: sweeps node counts × matrices × algorithms
//! × MPI flavors and reports the virtual SDDE time plus the paper's
//! red-dot metric (max inter-node messages per rank). One [`figures`]
//! sweep per paper figure (5–8); [`neighbor`] sweeps the steady-state
//! persistent neighborhood collectives; [`report`] renders tables/CSV.

pub mod figures;
pub mod neighbor;
pub mod report;

pub use figures::{
    run_once, run_once_traced, run_sweep, FigureId, Point, SweepConfig, Variant,
};
pub use neighbor::{
    run_halo_once, run_neighbor_sweep, HaloMethod, NeighborPoint, NeighborSweepConfig,
};
pub use report::{render_figure, render_neighbor_figure, write_csv, write_neighbor_csv};
