//! Chaos sweeps: a figure sweep repeated under a battery of seeded fault
//! plans, reporting per-seed makespan inflation against the fault-free
//! baseline and checking the traffic invariants the fault layer promises
//! (injection-time message/byte counters must not move under faults).
//!
//! This is the CLI-facing wrapper (`sdde chaos`, and `--faults` on the
//! figure commands); the pass/fail proofs of perturbation invariance live
//! in `tests/fault_invariance.rs`.

use super::figures::{run_sweep, Point, SweepConfig};
use crate::simnet::{FaultPlan, FaultProfile};
use crate::util::fmt;

/// One chaos sweep: a base figure configuration re-run under `seeds`
/// distinct fault plans sharing one profile.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Base sweep (its own `faults` field is ignored; the baseline runs
    /// fault-free and each chaos run installs a per-seed plan).
    pub base: SweepConfig,
    pub seeds: Vec<u64>,
    pub profile: FaultProfile,
}

impl ChaosConfig {
    pub fn new(base: SweepConfig, seeds: Vec<u64>, profile: FaultProfile) -> ChaosConfig {
        ChaosConfig {
            base,
            seeds,
            profile,
        }
    }
}

/// One faulted re-run of the base sweep.
#[derive(Clone, Debug)]
pub struct ChaosRun {
    pub seed: u64,
    pub points: Vec<Point>,
    /// Mean over points of `faulted time / baseline time`.
    pub mean_inflation: f64,
    /// Worst-case inflation and the point it occurred at.
    pub max_inflation: f64,
    pub max_label: String,
    /// Points whose dispatch-column pick changed vs. the fault-free
    /// baseline (non-zero only with a robustness-calibrated model loaded:
    /// the faulted re-runs dispatch under this profile's noise regime).
    pub dispatch_flips: usize,
}

/// Everything a chaos sweep measured.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub profile_name: String,
    pub baseline: Vec<Point>,
    pub runs: Vec<ChaosRun>,
    /// Traffic-invariance violations (empty on a healthy fault layer:
    /// faults may move time, never messages).
    pub violations: Vec<String>,
}

impl ChaosReport {
    pub fn traffic_invariant(&self) -> bool {
        self.violations.is_empty()
    }

    /// Plain-text table: one row per seed, inflation stats, plus the
    /// invariance verdict (the `sdde chaos` output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "-- chaos sweep: {} seed(s), profile {}, {} baseline point(s) --\n",
            self.runs.len(),
            self.profile_name,
            self.baseline.len()
        );
        let mut rows = vec![vec![
            "seed".to_string(),
            "mean inflation".to_string(),
            "max inflation".to_string(),
            "worst point".to_string(),
            "dispatch flips".to_string(),
        ]];
        for r in &self.runs {
            rows.push(vec![
                r.seed.to_string(),
                format!("{:.3}x", r.mean_inflation),
                format!("{:.3}x", r.max_inflation),
                r.max_label.clone(),
                r.dispatch_flips.to_string(),
            ]);
        }
        out.push_str(&fmt::table(&rows));
        if self.traffic_invariant() {
            out.push_str("traffic invariance: OK (faults moved time, not messages)\n");
        } else {
            out.push_str(&format!(
                "traffic invariance: {} VIOLATION(S)\n",
                self.violations.len()
            ));
            for v in &self.violations {
                out.push_str(&format!("  {v}\n"));
            }
        }
        out
    }
}

/// Run the baseline sweep fault-free, then once per seed under the
/// profile, comparing point-for-point.
pub fn run_chaos(cfg: &ChaosConfig) -> ChaosReport {
    let profile_name = profile_label(&cfg.profile);
    let mut base_cfg = cfg.base.clone();
    base_cfg.faults = None;
    // The baseline dispatches fault-free so the flip column is meaningful.
    base_cfg.noise = None;
    let baseline = run_sweep(&base_cfg);
    let mut runs = Vec::new();
    let mut violations = Vec::new();
    for &seed in &cfg.seeds {
        let mut c = cfg.base.clone();
        c.faults = Some(FaultPlan::with_profile(seed, cfg.profile));
        // Faulted re-runs dispatch under this profile's noise regime (a
        // no-op without a model; "off"/"custom" are not calibrated names).
        c.noise = match profile_name.as_str() {
            "off" | "custom" => None,
            name => Some(name.to_string()),
        };
        let points = run_sweep(&c);
        let mut sum = 0.0;
        let mut max = 0.0f64;
        let mut max_label = String::from("-");
        let mut n = 0usize;
        for (b, f) in baseline.iter().zip(&points) {
            debug_assert_eq!((b.matrix.as_str(), b.algo, b.nodes), (
                f.matrix.as_str(),
                f.algo,
                f.nodes
            ));
            if b.max_internode != f.max_internode || b.total_msgs != f.total_msgs {
                violations.push(format!(
                    "seed {seed} {} {} nodes={}: msgs {}→{}, max-internode {}→{}",
                    b.matrix, b.algo, b.nodes, b.total_msgs, f.total_msgs,
                    b.max_internode, f.max_internode
                ));
            }
            let ratio = f.time_ns as f64 / b.time_ns.max(1) as f64;
            sum += ratio;
            n += 1;
            if ratio > max {
                max = ratio;
                max_label = format!("{}/{}/n{}", b.matrix, b.algo, b.nodes);
            }
        }
        let dispatch_flips = baseline
            .iter()
            .zip(&points)
            .filter(|(b, f)| b.dispatch != f.dispatch)
            .count();
        runs.push(ChaosRun {
            seed,
            points,
            mean_inflation: if n > 0 { sum / n as f64 } else { 0.0 },
            max_inflation: max,
            max_label,
            dispatch_flips,
        });
    }
    ChaosReport {
        profile_name,
        baseline,
        runs,
        violations,
    }
}

/// Best-effort name for a profile (matches the CLI spellings for the
/// stock profiles; custom knob combinations print as "custom").
pub fn profile_label(p: &FaultProfile) -> String {
    for name in ["off", "light", "heavy", "jitter", "straggler", "rendezvous", "duplicate"] {
        if FaultProfile::parse(name).as_ref() == Ok(p) {
            return name.to_string();
        }
    }
    "custom".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::figures::FigureId;

    #[test]
    fn chaos_sweep_reports_inflation_and_invariance() {
        let mut base = SweepConfig::quick(FigureId::Fig5, 400);
        base.nodes = vec![2];
        base.matrices.truncate(1);
        let cfg = ChaosConfig::new(base, vec![1, 2], FaultProfile::heavy());
        let rep = run_chaos(&cfg);
        assert_eq!(rep.runs.len(), 2);
        assert!(rep.traffic_invariant(), "{:?}", rep.violations);
        for r in &rep.runs {
            assert_eq!(r.points.len(), rep.baseline.len());
            assert!(r.mean_inflation > 0.0);
            assert!(r.max_inflation >= r.mean_inflation * 0.5);
        }
        let text = rep.render();
        assert!(text.contains("chaos sweep"));
        assert!(text.contains("traffic invariance: OK"));
        assert!(text.contains("heavy"));
    }

    #[test]
    fn model_noise_flips_the_dispatch_column() {
        // With the embedded evidence model loaded, heavy-profile re-runs
        // dispatch under "heavy" noise; small/crs buckets flip from
        // personalized to nonblocking, so every point reports a flip.
        let mut base = SweepConfig::quick(FigureId::Fig5, 400);
        base.nodes = vec![2];
        base.matrices.truncate(1);
        base.dispatch = Some(crate::mpix::DispatchModel::embedded().clone());
        let cfg = ChaosConfig::new(base, vec![1], FaultProfile::heavy());
        let rep = run_chaos(&cfg);
        assert_eq!(rep.runs[0].dispatch_flips, rep.baseline.len());
        assert!(rep.render().contains("dispatch flips"));
    }

    #[test]
    fn profile_labels_roundtrip() {
        assert_eq!(profile_label(&FaultProfile::heavy()), "heavy");
        assert_eq!(profile_label(&FaultProfile::off()), "off");
        let mut p = FaultProfile::jitter();
        p.jitter_max_ns += 1;
        assert_eq!(profile_label(&p), "custom");
    }
}
