//! Per-tier × per-tag-family rollup of a trace.
//!
//! The rollup mirrors [`crate::mpi::Counters`]' semantics exactly —
//! messages counted at injection, user vs internal split at
//! [`crate::mpi::TAG_INTERNAL_BASE`], per-source-rank inter-node counts —
//! so the conservation tests can assert bit-for-bit agreement between the
//! two independent accounting paths. Unlike `Counters`, the rollup keys
//! messages by [`TagFamily`], so each algorithm layer's traffic is visible
//! separately (the per-tier table `sdde trace` prints).

use std::collections::BTreeMap;

use crate::simnet::Tier;
use crate::util::fmt;

use super::event::{tier_name, Event, EventKind, TagFamily};

/// Per-communicator-context slice of the rollup. Only contexts that saw
/// traffic get an entry; single-communicator runs therefore hold exactly
/// one (ctx 0) and render identically to the pre-context format.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CtxStats {
    /// Two-sided sends (eager + rendezvous) at injection.
    pub sends: u64,
    /// One-sided puts (no matching recv — excluded from conservation).
    pub rma_puts: u64,
    /// Wire bytes injected (sends + puts).
    pub bytes: u64,
    /// Arrivals matched by an already-posted receive.
    pub posted_matches: u64,
    /// Receives satisfied from the unexpected queue.
    pub unexpected_hits: u64,
}

impl CtxStats {
    /// Send↔recv conservation within the context: every two-sided send is
    /// consumed by exactly one match (duplicates deduped before matching).
    pub fn conserved(&self) -> bool {
        self.sends == self.posted_matches + self.unexpected_hits
    }
}

/// Rolled-up trace counters. Maintained incrementally by the
/// [`crate::trace::Tracer`] (counters mode) or recomputed from an event
/// list with [`TraceSummary::from_events`] (the two must agree).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// `[family][tier]` → messages (sends + RMA puts, at injection).
    pub msgs: [[u64; 4]; TagFamily::COUNT],
    /// `[family][tier]` → wire bytes.
    pub bytes: [[u64; 4]; TagFamily::COUNT],
    /// Per-source-rank count of *user* inter-node sends (the paper's
    /// red-dot numerator; mirrors `Counters::internode_sent`).
    pub internode_sent: Vec<u64>,
    pub eager_sends: u64,
    pub rendezvous_sends: u64,
    pub rma_puts: u64,
    /// Arrivals matched by an already-posted receive.
    pub posted_matches: u64,
    /// Receives satisfied from the unexpected queue.
    pub unexpected_hits: u64,
    /// Collective rounds completed (summed over ranks).
    pub coll_rounds: u64,
    /// Total `charge_cpu` busy time across ranks (ns).
    pub cpu_busy_ns: u64,
    /// Total time ranks spent blocked in `WaitAny` (ns).
    pub wait_ns: u64,
    /// Injected fault events (0 unless the world ran with a fault plan).
    pub fault_events: u64,
    /// Total virtual delay injected by faults (jitter + straggler
    /// dilation + duplicate retransmit offsets), ns. This is what `sdde
    /// trace` uses to attribute makespan inflation to injected faults.
    pub fault_delay_ns: u64,
    /// Per-context traffic slices (keyed by `CtxId.0`; ctx 0 = world).
    pub by_ctx: BTreeMap<u32, CtxStats>,
    /// Matches where the message and receive contexts differed. Zero by
    /// construction — reported so multi-pattern runs can prove isolation.
    /// Set by the tracer at drain time (`from_events` leaves it 0).
    pub cross_ctx_matches: u64,
}

impl TraceSummary {
    pub fn new(nranks: usize) -> TraceSummary {
        TraceSummary {
            internode_sent: vec![0; nranks],
            ..TraceSummary::default()
        }
    }

    /// Fold one event in (the single accounting rule both the live
    /// tracer and `from_events` use).
    pub fn record(&mut self, ev: &Event) {
        match ev.kind {
            EventKind::EagerSend | EventKind::RendezvousSend | EventKind::RmaPut => {
                let fam = ev.family();
                let (f, t) = (fam as usize, ev.tier as usize);
                self.msgs[f][t] += 1;
                self.bytes[f][t] += ev.bytes as u64;
                if fam.is_user()
                    && ev.tier == Tier::InterNode
                    && ev.rank < self.internode_sent.len()
                {
                    self.internode_sent[ev.rank] += 1;
                }
                let cs = self.by_ctx.entry(ev.ctx.0).or_default();
                cs.bytes += ev.bytes as u64;
                match ev.kind {
                    EventKind::EagerSend => {
                        self.eager_sends += 1;
                        cs.sends += 1;
                    }
                    EventKind::RendezvousSend => {
                        self.rendezvous_sends += 1;
                        cs.sends += 1;
                    }
                    _ => {
                        self.rma_puts += 1;
                        cs.rma_puts += 1;
                    }
                }
            }
            EventKind::RecvMatch => {
                self.posted_matches += 1;
                self.by_ctx.entry(ev.ctx.0).or_default().posted_matches += 1;
            }
            EventKind::UnexpectedHit => {
                self.unexpected_hits += 1;
                self.by_ctx.entry(ev.ctx.0).or_default().unexpected_hits += 1;
            }
            EventKind::CollRound => self.coll_rounds += 1,
            EventKind::CpuCharge => self.cpu_busy_ns += ev.duration(),
            EventKind::Wait => self.wait_ns += ev.duration(),
            // Fault events are annotations, not traffic: they must not
            // perturb any counter `Counters` mirrors (bit-compat under
            // fault injection is asserted by trace_conservation).
            EventKind::Fault => {
                self.fault_events += 1;
                self.fault_delay_ns += ev.duration();
            }
        }
    }

    /// Recompute a rollup from raw events (`nranks` sizes the per-rank
    /// inter-node vector).
    pub fn from_events(events: &[Event], nranks: usize) -> TraceSummary {
        let mut s = TraceSummary::new(nranks);
        for ev in events {
            s.record(ev);
        }
        s
    }

    /// Per-tier user messages (all families below the internal base) —
    /// comparable to `Counters::user_msgs`.
    pub fn user_msgs(&self) -> [u64; 4] {
        self.sum_families(&self.msgs, true)
    }

    /// Per-tier user wire bytes — comparable to `Counters::user_bytes`.
    pub fn user_bytes(&self) -> [u64; 4] {
        self.sum_families(&self.bytes, true)
    }

    /// Per-tier internal messages — comparable to `Counters::int_msgs`.
    pub fn internal_msgs(&self) -> [u64; 4] {
        self.msgs[TagFamily::Internal as usize]
    }

    /// Per-tier internal wire bytes — comparable to `Counters::int_bytes`.
    pub fn internal_bytes(&self) -> [u64; 4] {
        self.bytes[TagFamily::Internal as usize]
    }

    fn sum_families(&self, table: &[[u64; 4]; TagFamily::COUNT], user: bool) -> [u64; 4] {
        let mut out = [0u64; 4];
        for f in TagFamily::ALL {
            if f.is_user() == user {
                for (o, v) in out.iter_mut().zip(&table[f as usize]) {
                    *o += v;
                }
            }
        }
        out
    }

    /// The paper's red-dot metric: max per-rank user inter-node sends.
    pub fn max_internode_per_rank(&self) -> u64 {
        self.internode_sent.iter().copied().max().unwrap_or(0)
    }

    pub fn total_user_msgs(&self) -> u64 {
        self.user_msgs().iter().sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().flatten().sum()
    }

    /// Contexts that saw traffic beyond the world's (any ctx id != 0).
    pub fn has_multiple_ctx(&self) -> bool {
        self.by_ctx.keys().any(|&c| c != 0)
    }

    /// True when every context conserves two-sided sends against matches
    /// (the per-context send↔recv conservation invariant).
    pub fn conservation_ok(&self) -> bool {
        self.by_ctx.values().all(|cs| cs.conserved())
    }

    /// True when nothing was recorded (tracing off, or an empty run).
    pub fn is_empty(&self) -> bool {
        self.total_msgs() == 0
            && self.posted_matches == 0
            && self.unexpected_hits == 0
            && self.coll_rounds == 0
            && self.cpu_busy_ns == 0
            && self.wait_ns == 0
            && self.fault_events == 0
    }

    /// Render the per-tier × per-family tables plus the scalar counters
    /// as aligned plain text (the `sdde trace` report).
    pub fn render(&self, title: &str) -> String {
        let mut out = format!("-- trace summary: {title} --\n");
        let mut rows = vec![vec![
            "tier".to_string(),
            "user msgs".to_string(),
            "user bytes".to_string(),
            "internal msgs".to_string(),
            "internal bytes".to_string(),
        ]];
        let (um, ub) = (self.user_msgs(), self.user_bytes());
        let (im, ib) = (self.internal_msgs(), self.internal_bytes());
        for tier in [
            Tier::SelfMsg,
            Tier::IntraSocket,
            Tier::InterSocket,
            Tier::InterNode,
        ] {
            let t = tier as usize;
            rows.push(vec![
                tier_name(tier).to_string(),
                um[t].to_string(),
                fmt::bytes(ub[t]),
                im[t].to_string(),
                fmt::bytes(ib[t]),
            ]);
        }
        out.push_str(&fmt::table(&rows));
        let mut rows = vec![vec![
            "tag family".to_string(),
            "msgs".to_string(),
            "bytes".to_string(),
        ]];
        for f in TagFamily::ALL {
            let msgs: u64 = self.msgs[f as usize].iter().sum();
            let bytes: u64 = self.bytes[f as usize].iter().sum();
            if msgs > 0 {
                rows.push(vec![f.name().to_string(), msgs.to_string(), fmt::bytes(bytes)]);
            }
        }
        if rows.len() > 1 {
            out.push('\n');
            out.push_str(&fmt::table(&rows));
        }
        out.push_str(&format!(
            "\nsends: {} eager + {} rendezvous + {} rma-put; matches: {} posted + {} unexpected\n\
             coll rounds: {}; max inter-node msgs/rank: {}\n\
             cpu busy: {} total; wait: {} total\n",
            self.eager_sends,
            self.rendezvous_sends,
            self.rma_puts,
            self.posted_matches,
            self.unexpected_hits,
            self.coll_rounds,
            self.max_internode_per_rank(),
            fmt::ns(self.cpu_busy_ns),
            fmt::ns(self.wait_ns),
        ));
        if self.fault_events > 0 {
            out.push_str(&format!(
                "injected faults: {} events, {} total delay\n",
                self.fault_events,
                fmt::ns(self.fault_delay_ns),
            ));
        }
        out
    }

    /// Render the per-context breakdown (`--per-ctx`): one row per context
    /// that saw traffic, the conservation verdict, and the cross-context
    /// delivery audit. Not part of [`TraceSummary::render`] so the default
    /// single-communicator report stays byte-identical.
    pub fn render_per_ctx(&self) -> String {
        let mut out = String::from("-- per-context breakdown --\n");
        let mut rows = vec![vec![
            "ctx".to_string(),
            "sends".to_string(),
            "rma-puts".to_string(),
            "bytes".to_string(),
            "posted".to_string(),
            "unexpected".to_string(),
            "conserved".to_string(),
        ]];
        for (ctx, cs) in &self.by_ctx {
            rows.push(vec![
                ctx.to_string(),
                cs.sends.to_string(),
                cs.rma_puts.to_string(),
                fmt::bytes(cs.bytes),
                cs.posted_matches.to_string(),
                cs.unexpected_hits.to_string(),
                if cs.conserved() { "yes" } else { "NO" }.to_string(),
            ]);
        }
        out.push_str(&fmt::table(&rows));
        out.push_str(&format!(
            "cross-context deliveries: {}\n",
            self.cross_ctx_matches
        ));
        out.push_str(&format!(
            "per-context conservation: {}\n",
            if self.conservation_ok() { "OK" } else { "VIOLATED" }
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::mpi::CtxId;

    fn ev(kind: EventKind, rank: usize, tag: u32, bytes: usize, tier: Tier) -> Event {
        Event {
            kind,
            ctx: CtxId::WORLD,
            rank,
            peer: 0,
            tag,
            bytes,
            tier,
            t_start: 10,
            t_end: 30,
            msg_id: 1,
        }
    }

    fn ev_ctx(kind: EventKind, ctx: u32) -> Event {
        Event {
            ctx: CtxId(ctx),
            ..ev(kind, 0, 0x1000, 64, Tier::InterNode)
        }
    }

    #[test]
    fn per_ctx_rollup_and_conservation() {
        let events = [
            ev_ctx(EventKind::EagerSend, 0),
            ev_ctx(EventKind::RecvMatch, 0),
            ev_ctx(EventKind::EagerSend, 1),
            ev_ctx(EventKind::RendezvousSend, 1),
            ev_ctx(EventKind::UnexpectedHit, 1),
            ev_ctx(EventKind::RecvMatch, 1),
            ev_ctx(EventKind::RmaPut, 2),
        ];
        let s = TraceSummary::from_events(&events, 2);
        assert_eq!(s.by_ctx.len(), 3);
        assert_eq!(s.by_ctx[&0].sends, 1);
        assert_eq!(s.by_ctx[&1].sends, 2);
        assert_eq!(s.by_ctx[&1].posted_matches, 1);
        assert_eq!(s.by_ctx[&1].unexpected_hits, 1);
        assert_eq!(s.by_ctx[&2].rma_puts, 1);
        assert!(s.has_multiple_ctx());
        assert!(s.conservation_ok());
        let r = s.render_per_ctx();
        assert!(r.contains("cross-context deliveries: 0"));
        assert!(r.contains("per-context conservation: OK"));
    }

    #[test]
    fn unmatched_send_breaks_conservation() {
        let events = [
            ev_ctx(EventKind::EagerSend, 3),
            ev_ctx(EventKind::EagerSend, 3),
            ev_ctx(EventKind::RecvMatch, 3),
        ];
        let s = TraceSummary::from_events(&events, 2);
        assert!(!s.conservation_ok());
        assert!(s.render_per_ctx().contains("per-context conservation: VIOLATED"));
    }

    #[test]
    fn single_ctx_runs_keep_default_render_unchanged() {
        // The per-ctx breakdown lives in render_per_ctx only: render()
        // must not mention contexts for world-only traffic.
        let events = [ev(EventKind::EagerSend, 0, 0x1000, 64, Tier::InterNode)];
        let s = TraceSummary::from_events(&events, 2);
        assert!(!s.has_multiple_ctx());
        assert!(!s.render("t").contains("ctx"));
    }

    #[test]
    fn rollup_counts_sends_by_family_and_tier() {
        let events = [
            ev(EventKind::EagerSend, 0, 0x1000, 64, Tier::InterNode),
            ev(EventKind::EagerSend, 0, 0x1000, 32, Tier::IntraSocket),
            ev(EventKind::RendezvousSend, 1, 0x4000, 9000, Tier::InterNode),
            ev(EventKind::EagerSend, 1, 0xF000_0000, 8, Tier::InterNode),
            ev(EventKind::RecvMatch, 2, 0x1000, 64, Tier::InterNode),
            ev(EventKind::CpuCharge, 2, 0, 0, Tier::SelfMsg),
        ];
        let s = TraceSummary::from_events(&events, 4);
        assert_eq!(s.msgs[TagFamily::Sdde as usize][Tier::InterNode as usize], 1);
        assert_eq!(s.msgs[TagFamily::Sdde as usize][Tier::IntraSocket as usize], 1);
        assert_eq!(
            s.msgs[TagFamily::Neighbor as usize][Tier::InterNode as usize],
            1
        );
        assert_eq!(
            s.msgs[TagFamily::Internal as usize][Tier::InterNode as usize],
            1
        );
        // Internal sends do not count toward the red-dot metric.
        assert_eq!(s.internode_sent, vec![1, 1, 0, 0]);
        assert_eq!(s.max_internode_per_rank(), 1);
        assert_eq!(s.user_msgs(), [0, 1, 0, 2]);
        assert_eq!(s.user_bytes(), [0, 32, 0, 64 + 9000]);
        assert_eq!(s.internal_msgs(), [0, 0, 0, 1]);
        assert_eq!(s.total_user_msgs(), 3);
        assert_eq!(s.eager_sends, 3);
        assert_eq!(s.rendezvous_sends, 1);
        assert_eq!(s.posted_matches, 1);
        assert_eq!(s.cpu_busy_ns, 20);
        assert!(!s.is_empty());
    }

    #[test]
    fn empty_summary_is_empty() {
        assert!(TraceSummary::new(8).is_empty());
    }

    #[test]
    fn fault_events_are_annotations_not_traffic() {
        // A fault event (tag = fault code) must count toward the fault
        // rollup only: every counter Counters mirrors stays untouched.
        let events = [
            ev(EventKind::EagerSend, 0, 0x1000, 64, Tier::InterNode),
            ev(EventKind::Fault, 0, 0, 0, Tier::InterNode),
            ev(EventKind::Fault, 1, 1, 0, Tier::SelfMsg),
        ];
        let s = TraceSummary::from_events(&events, 2);
        assert_eq!(s.fault_events, 2);
        assert_eq!(s.fault_delay_ns, 40); // two 20 ns spans
        assert_eq!(s.total_msgs(), 1);
        assert_eq!(s.internode_sent, vec![1, 0]);
        assert_eq!(s.cpu_busy_ns, 0);
        let base = TraceSummary::from_events(&events[..1], 2);
        assert_eq!(s.msgs, base.msgs);
        assert_eq!(s.bytes, base.bytes);
        assert!(s.render("t").contains("injected faults: 2 events"));
        assert!(!base.render("t").contains("injected faults"));
    }

    #[test]
    fn render_contains_tiers_and_families() {
        let events = [ev(EventKind::EagerSend, 0, 0x1000, 64, Tier::InterNode)];
        let s = TraceSummary::from_events(&events, 2);
        let r = s.render("test");
        assert!(r.contains("inter-node"));
        assert!(r.contains("sdde"));
        assert!(r.contains("max inter-node msgs/rank: 1"));
    }
}
