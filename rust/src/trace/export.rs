//! Trace exporters: Chrome-trace JSON (one row per rank, loadable in
//! `chrome://tracing` / Perfetto) and a flat CSV.
//!
//! The JSON is written by hand — the crate deliberately has no serde —
//! against the Trace Event Format: an object with a `traceEvents` array of
//! `"ph":"X"` complete events (`ts`/`dur` in microseconds, fractional for
//! ns precision) plus `"ph":"M"` metadata naming each rank's row. All
//! emitted strings are fixed identifiers (kind/family/tier names), so no
//! JSON string escaping is needed.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::event::{tier_name, Event, TagFamily};

/// Render events as a Chrome-trace JSON string. `pid` 0 is the simulated
/// world; `tid` is the rank, so each rank gets its own track.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let nranks = events.iter().map(|e| e.rank.max(e.peer) + 1).max().unwrap_or(0);
    let mut s = String::with_capacity(events.len() * 160 + 256);
    s.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut sep = |s: &mut String| {
        if first {
            first = false;
        } else {
            s.push(',');
        }
    };
    for r in 0..nranks {
        sep(&mut s);
        let _ = write!(
            s,
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{r},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"rank {r}\"}}}}"
        );
    }
    for e in events {
        sep(&mut s);
        // ts/dur are µs floats in the trace format; keep ns precision.
        let ts = e.t_start as f64 / 1000.0;
        let dur = e.duration() as f64 / 1000.0;
        // World-context events keep the pre-context arg set byte for byte;
        // traffic on a derived communicator names its ctx.
        let ctx_arg = if e.ctx == crate::mpi::CtxId::WORLD {
            String::new()
        } else {
            format!(",\"ctx\":{}", e.ctx.0)
        };
        let _ = write!(
            s,
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{ts:.3},\
             \"dur\":{dur:.3},\"pid\":0,\"tid\":{},\"args\":{{\"peer\":{},\
             \"tag\":{},\"bytes\":{},\"tier\":\"{}\",\"msg\":{}{}}}}}",
            e.kind.name(),
            TagFamily::of(e.tag).name(),
            e.rank,
            e.peer,
            e.tag,
            e.bytes,
            tier_name(e.tier),
            e.msg_id,
            ctx_arg,
        );
    }
    s.push_str("]}");
    s
}

/// Write [`chrome_trace_json`] output to `path` (parent directories are
/// created).
pub fn write_chrome_trace(path: &Path, events: &[Event]) -> Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, chrome_trace_json(events))
        .with_context(|| format!("writing {}", path.display()))
}

/// Render events as CSV (one row per event, times in ns). The `ctx`
/// column is appended only when some event ran on a non-world context, so
/// single-communicator exports stay byte-identical to the old format.
pub fn trace_csv(events: &[Event]) -> String {
    let with_ctx = events.iter().any(|e| e.ctx != crate::mpi::CtxId::WORLD);
    trace_csv_opts(events, with_ctx)
}

/// Render events as CSV with an explicit choice about the trailing `ctx`
/// column (`--per-ctx` forces it on even for world-only traffic).
pub fn trace_csv_opts(events: &[Event], with_ctx: bool) -> String {
    let mut s = String::with_capacity(events.len() * 64 + 80);
    s.push_str("kind,family,rank,peer,tag,tier,bytes,t_start_ns,t_end_ns,msg_id");
    if with_ctx {
        s.push_str(",ctx");
    }
    s.push('\n');
    for e in events {
        let _ = write!(
            s,
            "{},{},{},{},{},{},{},{},{},{}",
            e.kind.name(),
            TagFamily::of(e.tag).name(),
            e.rank,
            e.peer,
            e.tag,
            tier_name(e.tier),
            e.bytes,
            e.t_start,
            e.t_end,
            e.msg_id,
        );
        if with_ctx {
            let _ = write!(s, ",{}", e.ctx.0);
        }
        s.push('\n');
    }
    s
}

/// Write [`trace_csv`] output to `path` (parent directories are created).
pub fn write_trace_csv(path: &Path, events: &[Event]) -> Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, trace_csv(events))
        .with_context(|| format!("writing {}", path.display()))
}

/// Write [`trace_csv_opts`] output to `path` (parent directories are
/// created).
pub fn write_trace_csv_opts(path: &Path, events: &[Event], with_ctx: bool) -> Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, trace_csv_opts(events, with_ctx))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::super::event::EventKind;
    use super::*;
    use crate::simnet::Tier;

    use crate::mpi::CtxId;

    fn sample() -> Vec<Event> {
        vec![
            Event {
                kind: EventKind::EagerSend,
                ctx: CtxId::WORLD,
                rank: 0,
                peer: 3,
                tag: 0x1000,
                bytes: 64,
                tier: Tier::InterNode,
                t_start: 1_000,
                t_end: 3_500,
                msg_id: 7,
            },
            Event {
                kind: EventKind::RecvMatch,
                ctx: CtxId::WORLD,
                rank: 3,
                peer: 0,
                tag: 0x1000,
                bytes: 64,
                tier: Tier::InterNode,
                t_start: 3_500,
                t_end: 3_700,
                msg_id: 7,
            },
        ]
    }

    /// Minimal structural JSON check: balanced braces/brackets outside
    /// strings, no trailing commas before closers.
    fn assert_valid_json_shape(s: &str) {
        let mut depth = 0i64;
        let mut in_str = false;
        let mut prev = ' ';
        for ch in s.chars() {
            if in_str {
                if ch == '"' && prev != '\\' {
                    in_str = false;
                }
            } else {
                match ch {
                    '"' => in_str = true,
                    '{' | '[' => depth += 1,
                    '}' | ']' => {
                        assert_ne!(prev, ',', "trailing comma before closer");
                        depth -= 1;
                        assert!(depth >= 0, "unbalanced closers");
                    }
                    _ => {}
                }
            }
            if !ch.is_whitespace() {
                prev = ch;
            }
        }
        assert_eq!(depth, 0, "unbalanced JSON");
        assert!(!in_str, "unterminated string");
    }

    #[test]
    fn chrome_json_structure() {
        let j = chrome_trace_json(&sample());
        assert_valid_json_shape(&j);
        assert!(j.starts_with("{\"displayTimeUnit\""));
        assert!(j.ends_with("]}"));
        assert!(j.contains("\"traceEvents\":["));
        assert!(j.contains("\"name\":\"rank 0\""));
        assert!(j.contains("\"name\":\"rank 3\""));
        assert!(j.contains("\"name\":\"eager-send\""));
        assert!(j.contains("\"ts\":1.000"));
        assert!(j.contains("\"dur\":2.500"));
        assert!(j.contains("\"tier\":\"inter-node\""));
    }

    #[test]
    fn chrome_json_empty_trace() {
        let j = chrome_trace_json(&[]);
        assert_valid_json_shape(&j);
        assert!(j.contains("\"traceEvents\":[]"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = trace_csv(&sample());
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("kind,family,rank"));
        assert_eq!(
            lines[1],
            "eager-send,sdde,0,3,4096,inter-node,64,1000,3500,7"
        );
        // World-only traffic: no ctx column anywhere (old byte-identical
        // format), and the chrome export carries no ctx arg.
        assert!(!lines[0].contains("ctx"));
        assert!(!chrome_trace_json(&sample()).contains("\"ctx\""));
    }

    #[test]
    fn csv_appends_ctx_column_for_multi_ctx_traces() {
        let mut evs = sample();
        evs[1].ctx = CtxId(2);
        let c = trace_csv(&evs);
        let lines: Vec<&str> = c.lines().collect();
        assert!(lines[0].ends_with(",ctx"));
        assert!(lines[1].ends_with(",0"));
        assert!(lines[2].ends_with(",2"));
        // Forced-on column for world-only traffic (--per-ctx).
        let forced = trace_csv_opts(&sample(), true);
        assert!(forced.lines().next().unwrap().ends_with(",ctx"));
        assert!(forced.lines().nth(1).unwrap().ends_with(",0"));
        // Chrome export names the ctx only on non-world events.
        let j = chrome_trace_json(&evs);
        assert_valid_json_shape(&j);
        assert_eq!(j.matches("\"ctx\":2").count(), 1);
    }

    #[test]
    fn write_roundtrip() {
        let dir = std::env::temp_dir().join("sdde_trace_export_test");
        let jpath = dir.join("t.json");
        let cpath = dir.join("t.csv");
        write_chrome_trace(&jpath, &sample()).unwrap();
        write_trace_csv(&cpath, &sample()).unwrap();
        assert!(std::fs::read_to_string(&jpath).unwrap().contains("traceEvents"));
        assert!(std::fs::read_to_string(&cpath).unwrap().contains("recv-match"));
        std::fs::remove_dir_all(dir).ok();
    }
}
