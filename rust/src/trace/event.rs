//! Typed trace events on the virtual clock.
//!
//! One [`Event`] is recorded per instrumented operation in the `mpi` layer:
//! message injections (eager and rendezvous), receive-side matches,
//! unexpected-queue hits, waits, collective rounds, RMA puts and CPU
//! charges. Events carry enough envelope (`rank`, `peer`, `tag`, `bytes`,
//! [`Tier`]) to roll up the paper's per-tier traffic metrics, and enough
//! causality (`msg_id` links a send to the recv that consumed it) for the
//! happens-before critical-path extractor in [`crate::trace::critical`].

use crate::mpi::{CtxId, Tag, TAG_INTERNAL_BASE};
use crate::simnet::{Tier, Time};

/// What an [`Event`] records.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// Eager-protocol send: `t_start` = injection start, `t_end` = arrival
    /// of the payload at the destination.
    EagerSend,
    /// Rendezvous-protocol send: the RTS leg only (`t_end` = RTS arrival);
    /// the data pull is charged inside the matching recv's span.
    RendezvousSend,
    /// A posted receive matched an arriving message (`t_start` = arrival,
    /// `t_end` = data available, including match cost and — for
    /// rendezvous — the CTS + data transfer).
    RecvMatch,
    /// A receive found its message already waiting in the unexpected
    /// queue (rendezvous: `t_end` covers the CTS + data pull).
    UnexpectedHit,
    /// A rank idle-waited in [`crate::mpi::WaitAny`] (NBX progress loops).
    Wait,
    /// One round of a p2p-built collective (allreduce / barrier /
    /// ibarrier) completed on this rank.
    CollRound,
    /// One-sided `MPI_Put` (origin-side; `t_end` = delivery at the target).
    RmaPut,
    /// [`crate::mpi::Comm::charge_cpu`] busy interval.
    CpuCharge,
    /// An injected fault fired (`tag` carries the `simnet::fault::FAULT_*`
    /// code; the span is the injected delay, zero-width for delayless
    /// perturbations). Never counted as message traffic — the rollup must
    /// stay bit-compatible with [`crate::mpi::Counters`] under faults.
    Fault,
}

impl EventKind {
    pub const ALL: [EventKind; 9] = [
        EventKind::EagerSend,
        EventKind::RendezvousSend,
        EventKind::RecvMatch,
        EventKind::UnexpectedHit,
        EventKind::Wait,
        EventKind::CollRound,
        EventKind::RmaPut,
        EventKind::CpuCharge,
        EventKind::Fault,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EventKind::EagerSend => "eager-send",
            EventKind::RendezvousSend => "rdv-send",
            EventKind::RecvMatch => "recv-match",
            EventKind::UnexpectedHit => "unexpected-hit",
            EventKind::Wait => "wait",
            EventKind::CollRound => "coll-round",
            EventKind::RmaPut => "rma-put",
            EventKind::CpuCharge => "cpu",
            EventKind::Fault => "fault",
        }
    }

    /// Kinds that inject traffic (the rollup counts these as messages,
    /// mirroring [`crate::mpi::Counters`]' injection-time accounting).
    pub fn is_send(&self) -> bool {
        matches!(
            self,
            EventKind::EagerSend | EventKind::RendezvousSend | EventKind::RmaPut
        )
    }
}

/// Which layer a user tag belongs to — the tag-space contract from
/// DESIGN.md, classified from the same constants the layers allocate from
/// (single source of truth; see `mpix::algos`, `mpix::neighbor`,
/// `solver::dist`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TagFamily {
    /// SDDE formation traffic (`MPIX_Alltoall(v)_crs`).
    Sdde = 0,
    /// Persistent neighbor alltoallv (data + forward channels).
    Neighbor = 1,
    /// Legacy per-exchange p2p halo.
    Halo = 2,
    /// User tags outside the named families (tests, examples, RMA puts).
    OtherUser = 3,
    /// Internal tags (collectives, barriers) at or above
    /// [`TAG_INTERNAL_BASE`].
    Internal = 4,
}

impl TagFamily {
    pub const COUNT: usize = 5;
    pub const ALL: [TagFamily; TagFamily::COUNT] = [
        TagFamily::Sdde,
        TagFamily::Neighbor,
        TagFamily::Halo,
        TagFamily::OtherUser,
        TagFamily::Internal,
    ];

    /// Classify a tag per the DESIGN.md tag-space table.
    pub fn of(tag: Tag) -> TagFamily {
        use crate::mpix::algos::TAG_SDDE;
        use crate::mpix::neighbor::TAG_NEIGHBOR;
        use crate::solver::dist::{TAG_HALO, TAG_HALO_WINDOW};
        if tag >= TAG_INTERNAL_BASE {
            TagFamily::Internal
        } else if (TAG_SDDE..TAG_SDDE + 0x2000).contains(&tag) {
            TagFamily::Sdde
        } else if (TAG_NEIGHBOR..TAG_NEIGHBOR + 0x4000).contains(&tag) {
            TagFamily::Neighbor
        } else if (TAG_HALO..TAG_HALO + TAG_HALO_WINDOW).contains(&tag) {
            TagFamily::Halo
        } else {
            TagFamily::OtherUser
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TagFamily::Sdde => "sdde",
            TagFamily::Neighbor => "neighbor",
            TagFamily::Halo => "halo",
            TagFamily::OtherUser => "other-user",
            TagFamily::Internal => "internal",
        }
    }

    pub fn is_user(&self) -> bool {
        *self != TagFamily::Internal
    }
}

/// Short label for a [`Tier`] (the topology layer has no name method; the
/// trace exporters and tables need one).
pub fn tier_name(tier: Tier) -> &'static str {
    match tier {
        Tier::SelfMsg => "self",
        Tier::IntraSocket => "intra-socket",
        Tier::InterSocket => "inter-socket",
        Tier::InterNode => "inter-node",
    }
}

/// One recorded operation. Times are virtual nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    /// Communicator context the operation ran on ([`CtxId::WORLD`] for
    /// world traffic and context-blind kinds like faults).
    pub ctx: CtxId,
    /// Rank the event is charged to (the sender for sends/puts, the
    /// receiver for matches, the waiter for waits). Always a *world* rank,
    /// even for events on split communicators.
    pub rank: usize,
    /// The other side (== `rank` for waits and CPU charges).
    pub peer: usize,
    /// Message tag (0 for tagless kinds: waits, CPU charges, RMA puts).
    pub tag: Tag,
    /// Wire bytes (0 for waits / CPU charges / barrier rounds).
    pub bytes: usize,
    pub tier: Tier,
    pub t_start: Time,
    pub t_end: Time,
    /// Nonzero for sends and the recv events they complete into; a send
    /// and its consuming recv share the same id (happens-before edge).
    pub msg_id: u64,
}

impl Event {
    pub fn duration(&self) -> Time {
        self.t_end.saturating_sub(self.t_start)
    }

    pub fn family(&self) -> TagFamily {
        TagFamily::of(self.tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_classification_matches_design_table() {
        assert_eq!(TagFamily::of(0x1000), TagFamily::Sdde);
        assert_eq!(TagFamily::of(0x2FFD), TagFamily::Sdde);
        assert_eq!(TagFamily::of(0x4000), TagFamily::Neighbor);
        assert_eq!(TagFamily::of(0x7FFF), TagFamily::Neighbor);
        assert_eq!(TagFamily::of(0x0010_0000), TagFamily::Halo);
        assert_eq!(TagFamily::of(0x00FF_FFFF), TagFamily::Halo);
        assert_eq!(TagFamily::of(0xF000_0000), TagFamily::Internal);
        assert_eq!(TagFamily::of(0xF510_0000), TagFamily::Internal);
        // Gaps between the named windows are plain user tags.
        assert_eq!(TagFamily::of(0), TagFamily::OtherUser);
        assert_eq!(TagFamily::of(0x3000), TagFamily::OtherUser);
        assert_eq!(TagFamily::of(0x8000), TagFamily::OtherUser);
        assert_eq!(TagFamily::of(0x0100_0000), TagFamily::OtherUser);
    }

    #[test]
    fn kind_send_classification() {
        assert!(EventKind::EagerSend.is_send());
        assert!(EventKind::RendezvousSend.is_send());
        assert!(EventKind::RmaPut.is_send());
        assert!(!EventKind::RecvMatch.is_send());
        assert!(!EventKind::Wait.is_send());
    }
}
