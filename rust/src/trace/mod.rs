//! Message-level tracing, per-tier counters, and critical-path profiling
//! for the simulated MPI stack.
//!
//! The paper's headline results rest on claims about *where* messages go —
//! fewer, larger inter-node messages in exchange for cheap intra-socket
//! ones. This layer makes that visible from one instrumented source of
//! truth instead of per-bench ad-hoc counting:
//!
//! * [`event`] — typed [`Event`]s on the virtual clock (eager/rendezvous
//!   send, recv match, unexpected-queue hit, wait, collective round, RMA
//!   put, CPU charge) with `(rank, peer, tag, bytes, tier, t_start,
//!   t_end)`, plus the [`TagFamily`] classification of DESIGN.md's
//!   tag-space table.
//! * [`summary`] — per-tier × per-family rollup ([`TraceSummary`]) that
//!   mirrors [`crate::mpi::Counters`] bit-for-bit on the shared metrics.
//! * [`export`] — Chrome-trace JSON (one row per rank; open in
//!   `chrome://tracing` or Perfetto) and CSV.
//! * [`critical`] — happens-before critical-path extraction: the longest
//!   send→recv→compute chain and each kind's / tag family's share of it.
//!
//! Tracing is **off by default** and zero-cost when disabled: every
//! instrumentation site is guarded by one inline `enabled()` bool check,
//! no event is constructed, and [`World::run`](crate::mpi::World::run)
//! returns an empty [`Trace`]. Enable it per `World` with
//! [`crate::mpi::World::with_trace`]:
//!
//! * [`TraceConfig::counters_only`] — maintain the rollup, drop the
//!   events (what `bench::figures` uses for trace-derived metrics).
//! * [`TraceConfig::full`] — record every event (exporters + critical
//!   path; what `sdde trace` uses).
//!
//! Recording is host-side only: it never charges virtual time, so traced
//! and untraced runs produce identical virtual end times.

use std::cell::{Cell, RefCell};

use crate::mpi::CtxId;

pub mod critical;
pub mod event;
pub mod export;
pub mod summary;

pub use critical::{critical_path, CriticalPath};
pub use event::{tier_name, Event, EventKind, TagFamily};
pub use export::{
    chrome_trace_json, trace_csv, trace_csv_opts, write_chrome_trace, write_trace_csv,
    write_trace_csv_opts,
};
pub use summary::{CtxStats, TraceSummary};

/// What a [`Tracer`] records. Default: nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceConfig {
    /// Maintain the [`TraceSummary`] rollup.
    pub counters: bool,
    /// Keep every [`Event`] (required by the exporters and the
    /// critical-path extractor; implies meaningful `msg_id`s).
    pub events: bool,
}

impl TraceConfig {
    /// Record nothing (the default for [`crate::mpi::World::new`]).
    pub fn off() -> TraceConfig {
        TraceConfig::default()
    }

    /// Rollup counters only — cheap enough for every bench run.
    pub fn counters_only() -> TraceConfig {
        TraceConfig {
            counters: true,
            events: false,
        }
    }

    /// Full event recording.
    pub fn full() -> TraceConfig {
        TraceConfig {
            counters: true,
            events: true,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.counters || self.events
    }
}

/// Everything recorded over one [`crate::mpi::World::run`].
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub config: TraceConfig,
    /// All events, in recording order (empty unless `config.events`).
    pub events: Vec<Event>,
    /// The live rollup (empty/zero unless `config.counters`).
    pub summary: TraceSummary,
}

impl Trace {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.summary.is_empty()
    }
}

/// Per-`World` event recorder. Owned by the world state; instrumentation
/// sites in the `mpi` layer call [`Tracer::record`] behind an
/// [`Tracer::enabled`] guard. Single-threaded like the executor —
/// interior mutability via `RefCell`/`Cell` only.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    events: RefCell<Vec<Event>>,
    summary: RefCell<TraceSummary>,
    next_id: Cell<u64>,
    /// Matches whose message context differed from the receive's context.
    /// Always 0 by construction (matching keys on ctx); counted anyway so
    /// the multi-pattern harness can *prove* isolation rather than assume
    /// it. Maintained even when tracing is off — it is one Cell write.
    cross_ctx: Cell<u64>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig, nranks: usize) -> Tracer {
        Tracer {
            cfg,
            events: RefCell::new(Vec::new()),
            summary: RefCell::new(if cfg.counters {
                TraceSummary::new(nranks)
            } else {
                TraceSummary::default()
            }),
            next_id: Cell::new(0),
            cross_ctx: Cell::new(0),
        }
    }

    /// A tracer that records nothing.
    pub fn disabled() -> Tracer {
        Tracer::new(TraceConfig::off(), 0)
    }

    /// The one guard every instrumentation site checks before building an
    /// event — a single bool load when tracing is off.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.counters || self.cfg.events
    }

    pub fn config(&self) -> TraceConfig {
        self.cfg
    }

    /// Fresh message id for a send (0 when disabled, so the disabled path
    /// allocates nothing and ids stay meaningless).
    #[inline]
    pub fn next_msg_id(&self) -> u64 {
        if !self.enabled() {
            return 0;
        }
        let id = self.next_id.get() + 1;
        self.next_id.set(id);
        id
    }

    /// Record one event (caller must have checked [`Tracer::enabled`]).
    pub fn record(&self, ev: Event) {
        if self.cfg.counters {
            self.summary.borrow_mut().record(&ev);
        }
        if self.cfg.events {
            self.events.borrow_mut().push(ev);
        }
    }

    /// Audit hook called at every match site with the message's and the
    /// receive's context ids. Equal by construction; a mismatch is counted
    /// (and `debug_assert`ed at the call sites) so trace summaries can
    /// report "cross-context deliveries: 0" as evidence, not assumption.
    #[inline]
    pub fn note_ctx_match(&self, msg_ctx: CtxId, spec_ctx: CtxId) {
        if msg_ctx != spec_ctx {
            self.cross_ctx.set(self.cross_ctx.get() + 1);
        }
    }

    /// Snapshot the rollup without consuming the tracer.
    pub fn summary_snapshot(&self) -> TraceSummary {
        let mut s = self.summary.borrow().clone();
        s.cross_ctx_matches = self.cross_ctx.get();
        s
    }

    /// Traced user inter-node sends by `rank` so far (0 when disabled or
    /// out of range) — the live red-dot accessor `bench::neighbor` uses.
    pub fn internode_sent(&self, rank: usize) -> u64 {
        self.summary
            .borrow()
            .internode_sent
            .get(rank)
            .copied()
            .unwrap_or(0)
    }

    /// Drain everything recorded into a [`Trace`] (end of a run).
    pub fn take(&self) -> Trace {
        let mut summary = self.summary.take();
        summary.cross_ctx_matches = self.cross_ctx.get();
        Trace {
            config: self.cfg,
            events: self.events.take(),
            summary,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::Tier;

    fn ev(id: u64) -> Event {
        Event {
            kind: EventKind::EagerSend,
            ctx: CtxId::WORLD,
            rank: 0,
            peer: 1,
            tag: 0x1000,
            bytes: 8,
            tier: Tier::InterNode,
            t_start: 0,
            t_end: 10,
            msg_id: id,
        }
    }

    #[test]
    fn ctx_match_audit_counts_only_mismatches() {
        let t = Tracer::new(TraceConfig::counters_only(), 2);
        t.note_ctx_match(CtxId::WORLD, CtxId::WORLD);
        t.note_ctx_match(CtxId(3), CtxId(3));
        assert_eq!(t.summary_snapshot().cross_ctx_matches, 0);
        t.note_ctx_match(CtxId(1), CtxId(2));
        assert_eq!(t.summary_snapshot().cross_ctx_matches, 1);
        assert_eq!(t.take().summary.cross_ctx_matches, 1);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert_eq!(t.next_msg_id(), 0);
        assert_eq!(t.next_msg_id(), 0);
        let trace = t.take();
        assert!(trace.is_empty());
        assert!(trace.events.is_empty());
    }

    #[test]
    fn counters_only_keeps_rollup_not_events() {
        let t = Tracer::new(TraceConfig::counters_only(), 4);
        assert!(t.enabled());
        let id = t.next_msg_id();
        assert_eq!(id, 1);
        t.record(ev(id));
        assert_eq!(t.internode_sent(0), 1);
        let trace = t.take();
        assert!(trace.events.is_empty());
        assert_eq!(trace.summary.total_user_msgs(), 1);
        assert!(!trace.is_empty());
    }

    #[test]
    fn full_mode_keeps_events_and_rollup_in_agreement() {
        let t = Tracer::new(TraceConfig::full(), 4);
        for _ in 0..5 {
            let id = t.next_msg_id();
            t.record(ev(id));
        }
        let trace = t.take();
        assert_eq!(trace.events.len(), 5);
        assert_eq!(
            trace.summary,
            TraceSummary::from_events(&trace.events, 4)
        );
    }

    #[test]
    fn msg_ids_are_unique_and_nonzero() {
        let t = Tracer::new(TraceConfig::full(), 2);
        let a = t.next_msg_id();
        let b = t.next_msg_id();
        assert!(a != 0 && b != 0 && a != b);
    }
}
