//! Happens-before critical-path extraction over a recorded trace.
//!
//! The virtual-time DAG has two edge kinds: *program order* (events on one
//! rank, ordered by time) and *message order* (a send happens-before the
//! recv that consumed it; the pair shares a `msg_id`). The critical path
//! is found by walking backward from the globally latest-ending event,
//! at each step moving to the latest-ending predecessor — the matching
//! send (for recv events) or the latest earlier event on the same rank —
//! the same longest-chain construction OTF2/Scalasca-style tools apply to
//! real MPI traces. The report attributes the chain's time to event kinds
//! and tag families, answering "which algorithm / which protocol leg is
//! the bottleneck".

use std::collections::HashMap;

use crate::simnet::Time;
use crate::util::fmt;

use super::event::{tier_name, Event, EventKind, TagFamily};

/// The extracted chain plus its attribution.
#[derive(Clone, Debug, Default)]
pub struct CriticalPath {
    /// Chain events in chronological order (last = latest-ending event).
    pub steps: Vec<Event>,
    /// `t_end` of the final event (the traced makespan).
    pub makespan_ns: Time,
    /// Sum of step durations (< makespan when the chain has idle gaps).
    pub covered_ns: Time,
    /// (kind, total ns on the chain), descending.
    pub by_kind: Vec<(EventKind, Time)>,
    /// (family, total ns on the chain) over tagged message events,
    /// descending — each algorithm layer's share of the bottleneck.
    pub by_family: Vec<(TagFamily, Time)>,
    /// (ctx id, total ns on the chain) over message events, descending —
    /// which communicator's pattern carries the bottleneck. Single-entry
    /// (ctx 0) for single-communicator runs.
    pub by_ctx: Vec<(u32, Time)>,
}

/// Extract the critical path of `events` (any order; empty in → empty out).
pub fn critical_path(events: &[Event]) -> CriticalPath {
    if events.is_empty() {
        return CriticalPath::default();
    }

    // msg_id → index of the send event that created the message.
    let mut send_of: HashMap<u64, usize> = HashMap::new();
    // rank → event indices sorted by t_end (local-predecessor lookup).
    let mut per_rank: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, e) in events.iter().enumerate() {
        if e.msg_id != 0
            && matches!(e.kind, EventKind::EagerSend | EventKind::RendezvousSend)
        {
            send_of.insert(e.msg_id, i);
        }
        per_rank.entry(e.rank).or_default().push(i);
    }
    for v in per_rank.values_mut() {
        v.sort_by_key(|&i| (events[i].t_end, i));
    }

    // Latest-ending event starts the backward walk.
    let mut cur = (0..events.len())
        .max_by_key(|&i| (events[i].t_end, i))
        .unwrap();
    let mut visited = vec![false; events.len()];
    visited[cur] = true;
    let mut chain = vec![cur];
    loop {
        let e = &events[cur];
        // Message predecessor: the send this recv consumed.
        let remote = match e.kind {
            EventKind::RecvMatch | EventKind::UnexpectedHit => {
                send_of.get(&e.msg_id).copied().filter(|&i| i != cur)
            }
            _ => None,
        };
        // Program-order predecessor: latest same-rank event ending at or
        // before this one starts (binary search over the t_end-sorted
        // list; skip already-visited entries to guarantee termination).
        let local = per_rank.get(&e.rank).and_then(|v| {
            let mut hi = v.partition_point(|&i| events[i].t_end <= e.t_start);
            while hi > 0 {
                hi -= 1;
                if !visited[v[hi]] {
                    return Some(v[hi]);
                }
            }
            None
        });
        let next = match (remote, local) {
            (Some(r), Some(l)) if !visited[r] => {
                if events[r].t_end >= events[l].t_end {
                    r
                } else {
                    l
                }
            }
            (Some(r), None) if !visited[r] => r,
            (_, Some(l)) => l,
            _ => break,
        };
        visited[next] = true;
        chain.push(next);
        cur = next;
    }
    chain.reverse();

    let steps: Vec<Event> = chain.iter().map(|&i| events[i]).collect();
    let makespan_ns = steps.last().map(|e| e.t_end).unwrap_or(0);
    let covered_ns = steps.iter().map(|e| e.duration()).sum();
    let mut by_kind_map: HashMap<EventKind, Time> = HashMap::new();
    let mut by_family_map: HashMap<TagFamily, Time> = HashMap::new();
    let mut by_ctx_map: HashMap<u32, Time> = HashMap::new();
    for e in &steps {
        *by_kind_map.entry(e.kind).or_default() += e.duration();
        if e.kind.is_send()
            || matches!(e.kind, EventKind::RecvMatch | EventKind::UnexpectedHit)
        {
            *by_family_map.entry(e.family()).or_default() += e.duration();
            *by_ctx_map.entry(e.ctx.0).or_default() += e.duration();
        }
    }
    let mut by_kind: Vec<_> = by_kind_map.into_iter().collect();
    by_kind.sort_by_key(|&(k, t)| (std::cmp::Reverse(t), k.name()));
    let mut by_family: Vec<_> = by_family_map.into_iter().collect();
    by_family.sort_by_key(|&(f, t)| (std::cmp::Reverse(t), f.name()));
    let mut by_ctx: Vec<_> = by_ctx_map.into_iter().collect();
    by_ctx.sort_by_key(|&(c, t)| (std::cmp::Reverse(t), c));

    CriticalPath {
        steps,
        makespan_ns,
        covered_ns,
        by_kind,
        by_family,
        by_ctx,
    }
}

impl CriticalPath {
    /// Human-readable report: shares by kind and family, then the tail of
    /// the chain itself.
    pub fn render(&self) -> String {
        if self.steps.is_empty() {
            return "-- critical path: (empty trace) --\n".to_string();
        }
        let mut out = format!(
            "-- critical path: {} over {} steps ({} on-chain, {} gaps) --\n",
            fmt::ns(self.makespan_ns),
            self.steps.len(),
            fmt::ns(self.covered_ns),
            fmt::ns(self.makespan_ns.saturating_sub(self.covered_ns)),
        );
        let pct = |t: Time| {
            if self.makespan_ns == 0 {
                0.0
            } else {
                100.0 * t as f64 / self.makespan_ns as f64
            }
        };
        out.push_str("share by kind:   ");
        for (i, (k, t)) in self.by_kind.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            let _ = std::fmt::Write::write_fmt(
                &mut out,
                format_args!("{} {:.1}%", k.name(), pct(*t)),
            );
        }
        out.push('\n');
        if !self.by_family.is_empty() {
            out.push_str("share by family: ");
            for (i, (f, t)) in self.by_family.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = std::fmt::Write::write_fmt(
                    &mut out,
                    format_args!("{} {:.1}%", f.name(), pct(*t)),
                );
            }
            out.push('\n');
        }
        // Per-context attribution appears only when more than one context
        // contributed, so single-communicator reports are unchanged.
        if self.by_ctx.len() > 1 {
            out.push_str("share by ctx:    ");
            for (i, (c, t)) in self.by_ctx.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = std::fmt::Write::write_fmt(
                    &mut out,
                    format_args!("ctx {} {:.1}%", c, pct(*t)),
                );
            }
            out.push('\n');
        }
        let tail = self.steps.len().saturating_sub(12);
        if tail > 0 {
            let _ = std::fmt::Write::write_fmt(
                &mut out,
                format_args!("chain tail (last 12 of {} steps):\n", self.steps.len()),
            );
        } else {
            out.push_str("chain:\n");
        }
        for e in &self.steps[tail..] {
            let _ = std::fmt::Write::write_fmt(
                &mut out,
                format_args!(
                    "  [{:>12} .. {:>12}] {:<14} rank {} -> {} tag {:#x} {} ({})\n",
                    e.t_start,
                    e.t_end,
                    e.kind.name(),
                    e.rank,
                    e.peer,
                    e.tag,
                    fmt::bytes(e.bytes as u64),
                    tier_name(e.tier),
                ),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::Tier;

    use crate::mpi::CtxId;

    fn ev(
        kind: EventKind,
        rank: usize,
        peer: usize,
        t_start: Time,
        t_end: Time,
        msg_id: u64,
    ) -> Event {
        Event {
            kind,
            ctx: CtxId::WORLD,
            rank,
            peer,
            tag: 0x1000,
            bytes: 8,
            tier: Tier::InterNode,
            t_start,
            t_end,
            msg_id,
        }
    }

    #[test]
    fn ctx_attribution_splits_by_context() {
        let mut send = ev(EventKind::EagerSend, 0, 1, 0, 300, 1);
        send.ctx = CtxId(2);
        let mut recv = ev(EventKind::RecvMatch, 1, 0, 300, 350, 1);
        recv.ctx = CtxId(2);
        let cp = critical_path(&[send, recv]);
        assert_eq!(cp.by_ctx, vec![(2, 350)]);
        // Single-context chain: no per-ctx line in the report.
        assert!(!cp.render().contains("share by ctx"));
    }

    #[test]
    fn empty_trace_gives_empty_path() {
        let cp = critical_path(&[]);
        assert!(cp.steps.is_empty());
        assert_eq!(cp.makespan_ns, 0);
        assert!(cp.render().contains("empty trace"));
    }

    #[test]
    fn follows_send_recv_chain_across_ranks() {
        // rank 0: cpu [0,100], send [100,300] (msg 1)
        // rank 1: recv-match [300,320] (msg 1), cpu [320,500],
        //         send [500,700] (msg 2)
        // rank 2: recv-match [700,730] (msg 2)
        let events = [
            ev(EventKind::CpuCharge, 0, 0, 0, 100, 0),
            ev(EventKind::EagerSend, 0, 1, 100, 300, 1),
            ev(EventKind::RecvMatch, 1, 0, 300, 320, 1),
            ev(EventKind::CpuCharge, 1, 1, 320, 500, 0),
            ev(EventKind::EagerSend, 1, 2, 500, 700, 2),
            ev(EventKind::RecvMatch, 2, 1, 700, 730, 2),
        ];
        let cp = critical_path(&events);
        assert_eq!(cp.makespan_ns, 730);
        // The chain crosses both messages and all three ranks.
        assert_eq!(cp.steps.len(), 6);
        assert_eq!(cp.steps[0].kind, EventKind::CpuCharge);
        assert_eq!(cp.steps[0].rank, 0);
        assert_eq!(cp.steps[5].rank, 2);
        assert_eq!(cp.covered_ns, 100 + 200 + 20 + 180 + 200 + 30);
        // Fully covered: no gaps in this chain.
        assert_eq!(cp.covered_ns, cp.makespan_ns);
    }

    #[test]
    fn prefers_later_ending_predecessor() {
        // Two sends could explain the final recv's start; the walk must
        // pick the message edge (ends at 400) over the local event
        // (ends at 50).
        let events = [
            ev(EventKind::CpuCharge, 1, 1, 0, 50, 0),
            ev(EventKind::EagerSend, 0, 1, 100, 400, 9),
            ev(EventKind::RecvMatch, 1, 0, 400, 450, 9),
        ];
        let cp = critical_path(&events);
        assert_eq!(cp.steps.len(), 2);
        assert_eq!(cp.steps[0].kind, EventKind::EagerSend);
    }

    #[test]
    fn terminates_on_adversarial_overlaps() {
        // Identical times everywhere — the visited guard must still
        // terminate and never revisit an event.
        let events: Vec<Event> = (0..32)
            .map(|i| ev(EventKind::CpuCharge, i % 4, i % 4, 100, 100, 0))
            .collect();
        let cp = critical_path(&events);
        assert!(cp.steps.len() <= events.len());
    }

    #[test]
    fn attribution_sums_to_covered() {
        let events = [
            ev(EventKind::EagerSend, 0, 1, 0, 300, 1),
            ev(EventKind::RecvMatch, 1, 0, 300, 350, 1),
        ];
        let cp = critical_path(&events);
        let kind_total: Time = cp.by_kind.iter().map(|&(_, t)| t).sum();
        assert_eq!(kind_total, cp.covered_ns);
        let fam_total: Time = cp.by_family.iter().map(|&(_, t)| t).sum();
        assert_eq!(fam_total, 350);
        assert!(cp.render().contains("share by kind"));
    }
}
