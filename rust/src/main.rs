//! `sdde` — CLI launcher for the SDDE reproduction.
//!
//! Subcommands:
//! * `figures`  — regenerate the paper's Figures 5–8 (tables + CSV).
//! * `neighbor` — steady-state persistent neighbor-alltoallv figure
//!   (amortized setup + locality aggregation, across iteration counts).
//! * `sdde`     — run a single SDDE instance and print details.
//! * `trace`    — run one fully-traced SDDE: per-tier/per-family summary,
//!   critical path, Chrome-trace JSON (+ optional CSV) export.
//! * `solve`    — distributed CG/Jacobi solve over an SDDE-formed pattern.
//! * `chaos`    — re-run a figure sweep under a battery of seeded fault
//!   plans; report makespan inflation and check traffic invariance. With
//!   `--patterns K`, run K *concurrent* SDDEs in one faulted world (one
//!   derived communicator per pattern) and check per-context send↔recv
//!   conservation, zero cross-context deliveries, and agreement with
//!   serial single-pattern oracles.
//! * `dispatch` — print the evidence model's decision table for a pattern
//!   regime (which algorithm wins per noise profile, and why); `--split`
//!   re-runs the decision on a node-parity split communicator.
//! * `calibrate`— run figure + chaos sweeps and distill a dispatch model
//!   (JSON) from the measured base costs, fault inflation and
//!   critical-path wait shares.
//! * `info`     — list matrix presets, algorithms and cost-model presets.
//!
//! `figures`, `neighbor`, `sdde` and `trace` accept
//! `--faults SEED[:PROFILE]` to inject seeded network perturbation
//! (jitter, stragglers, forced rendezvous, duplicate delivery); results
//! must not change, only virtual time may. All sweep commands accept
//! `--dispatch-model embedded|none|PATH` (+ `--noise PROFILE`) to drive
//! the dispatch layer from calibrated evidence instead of the legacy
//! heuristic.
//!
//! Examples:
//! ```text
//! sdde figures --fig 7 --quick
//! sdde figures --fig all --out results/
//! sdde figures --fig 5 --quick --faults 42:heavy
//! sdde neighbor --nodes 2,4 --iters 1,16,256 --mpi both
//! sdde sdde --matrix cage14 --nodes 8 --algo loc-nonblocking --variant v
//! sdde trace --matrix cage14 --div 16 --nodes 4 --ppn 8 --out trace.json
//! sdde solve --nx 48 --ny 48 --nodes 2 --ppn 4 --solver cg --halo loc
//! sdde chaos --fig 5 --div 400 --nseeds 8 --profile heavy
//! sdde dispatch --nodes 4 --ppn 8 --variant v
//! sdde calibrate --div 400 --nodes 2,4 --profiles heavy,jitter --out model.json
//! ```

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use sdde::bench::{
    oracle_digests, pattern_set_stats, pattern_set_stats_for, profile_label, render_figure,
    render_neighbor_figure, resolve_jobs, run_calibrate, run_chaos, run_multi,
    run_neighbor_sweep_bench, run_sweep_bench, write_bench_json, write_csv,
    write_neighbor_csv, CalibrateConfig, ChaosConfig, FigureId, HaloMethod, MultiConfig,
    NeighborSweepConfig, ProgressSink, RunSpec, SweepBench, SweepConfig, Variant,
};
use sdde::mpi::World;
use sdde::mpix::{dispatch, DispatchModel, MpixComm, MpixInfo, NeighborMethod, SddeAlgorithm};
use sdde::simnet::{CostModel, FaultPlan, FaultProfile, MpiFlavor, RegionKind, Topology};
use sdde::solver::{cg, jacobi, CsrLocal, DistMatrix};
use sdde::sparse::{form_commpkg, MatrixPreset, Partition, SpmvPattern};
use sdde::trace::{
    critical_path, write_chrome_trace, write_trace_csv, write_trace_csv_opts, TraceConfig,
};
use sdde::util::{fmt, Args};
use std::rc::Rc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "figures" => cmd_figures(&args),
        "neighbor" => cmd_neighbor(&args),
        "sdde" => cmd_sdde(&args),
        "trace" => cmd_trace(&args),
        "solve" => cmd_solve(&args),
        "chaos" => cmd_chaos(&args),
        "dispatch" => cmd_dispatch(&args),
        "calibrate" => cmd_calibrate(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "sdde — A More Scalable Sparse Dynamic Data Exchange (reproduction)\n\n\
         USAGE: sdde <figures|neighbor|sdde|trace|solve|chaos|dispatch|calibrate|info> [flags]\n\n\
         figures --fig <5|6|7|8|all> [--quick] [--div N] [--out DIR]\n\
                 [--nodes 2,4,..] [--ppn N] [--matrices a,b] [--algos x,y]\n\
                 [--region node|socket] [--seed N] [--jobs N]\n\
                 [--faults SEED[:PROFILE]] [--bench-json FILE]\n\
                 [--dispatch-model embedded|none|PATH] [--noise PROFILE]\n\
         neighbor [--nodes 2,4,..] [--ppn N] [--iters 1,16,256] [--div N]\n\
                 [--matrices a,b] [--methods p2p,persistent,loc-persistent]\n\
                 [--mpi openmpi|mvapich2|both] [--region node|socket]\n\
                 [--out DIR] [--seed N] [--jobs N]\n\
                 [--faults SEED[:PROFILE]] [--bench-json FILE]\n\
                 [--dispatch-model embedded|none|PATH] [--noise PROFILE]\n\
         sdde    --matrix <preset> --nodes N [--ppn N] [--algo NAME]\n\
                 [--variant crs|v] [--mpi openmpi|mvapich2] [--div N]\n\
                 [--faults SEED[:PROFILE]]\n\
                 [--dispatch-model embedded|none|PATH] [--noise PROFILE]\n\
         trace   [--matrix <preset>] [--div N] [--nodes N] [--ppn N]\n\
                 [--algo NAME] [--variant crs|v] [--mpi openmpi|mvapich2]\n\
                 [--seed N] [--faults SEED[:PROFILE]] [--per-ctx]\n\
                 [--out FILE.json] [--csv FILE.csv]\n\
         solve   [--nx N --ny N] [--nodes N --ppn N] [--solver cg|jacobi]\n\
                 [--algo NAME] [--iters N] [--halo p2p|standard|loc]\n\
         chaos   [--fig 5|6|7|8] [--div N] [--nodes 2,4,..] [--ppn N]\n\
                 [--matrices a,b] [--nseeds N | --seeds 1,2,..]\n\
                 [--profile light|heavy|jitter|straggler|rendezvous|duplicate]\n\
                 [--jobs N] [--dispatch-model embedded|none|PATH]\n\
                 [--patterns K] (multi-pattern mode; then also:\n\
                 [--matrix <preset>] [--algo NAME] [--variant crs|v]\n\
                 [--faults SEED[:PROFILE]] [--per-ctx] [--csv FILE.csv])\n\
         dispatch [--matrix <preset>] [--div N] [--nodes N] [--ppn N]\n\
                 [--variant crs|v] [--region node|socket] [--seed N]\n\
                 [--dispatch-model embedded|none|PATH] [--split]\n\
         calibrate [--figs 5,7|all] [--div N] [--nodes 2,4] [--ppn N]\n\
                 [--matrices a,b] [--profiles light,heavy,jitter,straggler]\n\
                 [--nseeds N | --seeds 1,2,..] [--robustness W]\n\
                 [--jobs N] [--out FILE.json] [--quiet]\n\
         info\n\n\
         fault profiles: light heavy jitter straggler rendezvous duplicate"
    );
}

/// Shared `--faults SEED[:PROFILE]` parser; `None` when the flag is
/// absent (fault-free, bit-identical to before the fault layer existed).
fn parse_faults(args: &Args) -> Result<Option<FaultPlan>> {
    match args.get("faults") {
        None => Ok(None),
        Some(s) => FaultPlan::parse(s)
            .map(Some)
            .map_err(|e| anyhow!("bad --faults {s}: {e}")),
    }
}

/// Shared `--dispatch-model embedded|none|PATH` parser. The flag being
/// absent yields the embedded model only when `default_embedded` is set
/// (`sdde dispatch`); everywhere else absence means "no model" — the
/// legacy heuristic, bit-identical to the pre-model CLI.
fn parse_dispatch(args: &Args, default_embedded: bool) -> Result<Option<DispatchModel>> {
    match args.get("dispatch-model") {
        None => Ok(default_embedded.then(|| DispatchModel::embedded().clone())),
        Some("none") | Some("heuristic") => Ok(None),
        Some("embedded") | Some("default") => Ok(Some(DispatchModel::embedded().clone())),
        Some(path) => DispatchModel::load(Path::new(path)).map(Some),
    }
}

fn parse_noise(args: &Args) -> Option<String> {
    args.get("noise").map(|s| s.to_string())
}

fn parse_count(s: &str) -> Result<usize, String> {
    s.parse::<usize>()
        .ok()
        .filter(|&v| v > 0)
        .ok_or_else(|| "want a positive integer".to_string())
}

fn parse_algo(s: &str) -> Result<SddeAlgorithm, String> {
    SddeAlgorithm::parse(s)
}

fn parse_variant(args: &Args, default: &str) -> Result<Variant> {
    match args.get_or("variant", default) {
        "v" | "alltoallv" => Ok(Variant::Variable),
        "crs" | "alltoall" => Ok(Variant::ConstSize),
        v => bail!("unknown variant {v} (want crs|v)"),
    }
}

fn cmd_figures(args: &Args) -> Result<()> {
    let figs: Vec<FigureId> = match args.get_or("fig", "all") {
        "all" => vec![FigureId::Fig5, FigureId::Fig6, FigureId::Fig7, FigureId::Fig8],
        s => vec![FigureId::parse(s).ok_or_else(|| anyhow!("unknown figure {s}"))?],
    };
    let quick = args.has("quick");
    let div = args.get_parsed("div", if quick { 64 } else { 1 });
    let out_dir = args.get("out").map(PathBuf::from);
    // --jobs beats SDDE_JOBS beats serial; results are identical either way.
    let jobs = resolve_jobs(args.get("jobs").and_then(|s| s.parse().ok()));
    let faults = parse_faults(args)?;
    let dispatch_model = parse_dispatch(args, false)?;
    let noise = parse_noise(args);
    let mut benches: Vec<(String, SweepBench)> = Vec::new();

    for fig in figs {
        let mut cfg = if quick {
            SweepConfig::quick(fig, div)
        } else {
            SweepConfig::paper(fig)
        };
        if !quick && div > 1 {
            cfg.matrices = cfg.matrices.iter().map(|m| m.scaled(div)).collect();
        }
        cfg.nodes = args
            .get_list_with("nodes", cfg.nodes, parse_count)
            .map_err(|e| anyhow!(e))?;
        cfg.ppn = args.get_parsed("ppn", cfg.ppn);
        cfg.seed = args.get_parsed("seed", cfg.seed);
        if let Some(r) = args.get("region") {
            cfg.region = RegionKind::parse(r).ok_or_else(|| anyhow!("unknown region {r}"))?;
        }
        if let Some(ms) = args.get_list("matrices") {
            cfg.matrices = ms
                .iter()
                .map(|m| {
                    MatrixPreset::parse(m)
                        .map(|p| if div > 1 { p.scaled(div) } else { p })
                        .ok_or_else(|| anyhow!("unknown matrix {m}"))
                })
                .collect::<Result<_>>()?;
        }
        cfg.algos = args
            .get_list_with("algos", cfg.algos, parse_algo)
            .map_err(|e| anyhow!(e))?;
        cfg.jobs = jobs;
        cfg.faults = faults;
        cfg.dispatch = dispatch_model.clone();
        cfg.noise = noise.clone();
        let fig_no = match fig {
            FigureId::Fig5 => 5,
            FigureId::Fig6 => 6,
            FigureId::Fig7 => 7,
            FigureId::Fig8 => 8,
        };
        let (points, bench) = run_sweep_bench(&cfg);
        eprintln!("{}", bench.render(&format!("fig{fig_no}")));
        benches.push((format!("fig{fig_no}"), bench));
        println!("{}", render_figure(&fig.title(), &points));
        if let Some(dir) = &out_dir {
            let name = format!("fig{}_{}.csv", fig_no, cfg.flavor.name());
            let path = dir.join(name);
            write_csv(&path, &points)?;
            println!("wrote {}", path.display());
        }
    }
    if let Some(bp) = args.get("bench-json") {
        let path = PathBuf::from(bp);
        write_bench_json(&path, &benches)?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_neighbor(args: &Args) -> Result<()> {
    let div = args.get_parsed("div", 16usize);
    let flavors: Vec<MpiFlavor> = match args.get_or("mpi", "both") {
        "both" | "all" => vec![MpiFlavor::Mvapich2, MpiFlavor::OpenMpi],
        s => vec![MpiFlavor::parse(s).ok_or_else(|| anyhow!("unknown mpi flavor {s}"))?],
    };
    let out_dir = args.get("out").map(PathBuf::from);
    let jobs = resolve_jobs(args.get("jobs").and_then(|s| s.parse().ok()));
    let faults = parse_faults(args)?;
    let dispatch_model = parse_dispatch(args, false)?;
    let noise = parse_noise(args);
    let mut benches: Vec<(String, SweepBench)> = Vec::new();
    for flavor in flavors {
        let mut cfg = NeighborSweepConfig::quick(flavor, div);
        cfg.nodes = args
            .get_list_with("nodes", cfg.nodes, parse_count)
            .map_err(|e| anyhow!(e))?;
        cfg.ppn = args.get_parsed("ppn", cfg.ppn);
        cfg.seed = args.get_parsed("seed", cfg.seed);
        cfg.iters = args
            .get_list_with("iters", cfg.iters, parse_count)
            .map_err(|e| anyhow!(e))?;
        if let Some(r) = args.get("region") {
            cfg.region = RegionKind::parse(r).ok_or_else(|| anyhow!("unknown region {r}"))?;
        }
        if let Some(ms) = args.get_list("matrices") {
            cfg.matrices = ms
                .iter()
                .map(|m| {
                    MatrixPreset::parse(m)
                        .map(|p| if div > 1 { p.scaled(div) } else { p })
                        .ok_or_else(|| anyhow!("unknown matrix {m}"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(mm) = args.get_list("methods") {
            cfg.methods = mm
                .iter()
                .map(|m| {
                    HaloMethod::parse(m).ok_or_else(|| anyhow!("unknown halo method {m}"))
                })
                .collect::<Result<_>>()?;
        }
        cfg.algo = args
            .get_with("algo", cfg.algo, parse_algo)
            .map_err(|e| anyhow!(e))?;
        cfg.progress = ProgressSink::Stderr;
        cfg.jobs = jobs;
        cfg.faults = faults;
        cfg.dispatch = dispatch_model.clone();
        cfg.noise = noise.clone();
        let (points, bench) = run_neighbor_sweep_bench(&cfg);
        eprintln!("{}", bench.render(&format!("neighbor-{}", flavor.name())));
        benches.push((format!("neighbor-{}", flavor.name()), bench));
        let title = format!(
            "Neighbor figure: persistent neighbor alltoallv using {}",
            flavor.name()
        );
        println!("{}", render_neighbor_figure(&title, &points));
        if let Some(dir) = &out_dir {
            let path = dir.join(format!("fig_neighbor_{}.csv", flavor.name()));
            write_neighbor_csv(&path, &points)?;
            println!("wrote {}", path.display());
        }
    }
    if let Some(bp) = args.get("bench-json") {
        let path = PathBuf::from(bp);
        write_bench_json(&path, &benches)?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_sdde(args: &Args) -> Result<()> {
    let matrix = args.get_or("matrix", "cage14");
    let div = args.get_parsed("div", 1usize);
    let preset = MatrixPreset::parse(matrix)
        .map(|p| if div > 1 { p.scaled(div) } else { p })
        .ok_or_else(|| anyhow!("unknown matrix preset {matrix}"))?;
    let nodes = args.get_parsed("nodes", 4usize);
    let ppn = args.get_parsed("ppn", 32usize);
    let algo = args
        .get_with("algo", SddeAlgorithm::Dispatch, parse_algo)
        .map_err(|e| anyhow!(e))?;
    let flavor = MpiFlavor::parse(args.get_or("mpi", "mvapich2"))
        .ok_or_else(|| anyhow!("unknown mpi flavor"))?;
    let variant = parse_variant(args, "v")?;
    let seed = args.get_parsed("seed", 2023u64);
    let faults = parse_faults(args)?;
    let dispatch_model = parse_dispatch(args, false)?;
    let noise = parse_noise(args);

    let topo = Topology::quartz(nodes, ppn);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);
    eprintln!(
        "matrix={} n={} ranks={} ({} nodes x {} ppn), algo={}, mpi={}",
        preset.name,
        preset.n,
        nranks,
        nodes,
        ppn,
        algo.name(),
        flavor.name()
    );
    let patterns: Rc<Vec<SpmvPattern>> = Rc::new(
        (0..nranks)
            .map(|r| SpmvPattern::build(&preset, part, r, seed))
            .collect(),
    );
    let send_nnz: Vec<usize> = patterns.iter().map(|p| p.recv_nnz()).collect();
    eprintln!(
        "pattern: mean dests/rank = {:.1}, max = {}",
        send_nnz.iter().sum::<usize>() as f64 / nranks as f64,
        send_nnz.iter().max().unwrap()
    );
    if algo == SddeAlgorithm::Dispatch {
        // Show the decision before the run (aggregate pattern regime).
        let stats = pattern_set_stats(&topo, RegionKind::Node, variant, &patterns);
        let sel = dispatch::select(dispatch_model.as_ref(), &stats, noise.as_deref());
        eprintln!("dispatch: {} — {}", sel.algo.name(), sel.rationale);
    }
    let run = RunSpec::new(topo, flavor)
        .algo(algo)
        .seed(seed)
        .faults(faults)
        .dispatch(dispatch_model)
        .noise(noise)
        .run_sdde(variant, patterns);
    let summary = run.summary();
    println!("SDDE time (max over ranks): {}", fmt::ns(run.time_ns));
    println!(
        "max inter-node msgs/rank: {}   total user msgs: {}",
        summary.max_internode_per_rank(),
        summary.total_user_msgs()
    );
    println!(
        "per-tier msgs [self, intra-socket, inter-socket, inter-node]: {:?}",
        summary.user_msgs()
    );
    if summary.fault_events > 0 {
        println!(
            "injected faults: {} events, {} total delay",
            summary.fault_events,
            fmt::ns(summary.fault_delay_ns)
        );
    }
    Ok(())
}

/// One fully-traced SDDE run: per-tier/per-family summary table, critical
/// path, Chrome-trace JSON export (plus optional CSV).
fn cmd_trace(args: &Args) -> Result<()> {
    let matrix = args.get_or("matrix", "cage14");
    let div = args.get_parsed("div", 16usize);
    let preset = MatrixPreset::parse(matrix)
        .map(|p| if div > 1 { p.scaled(div) } else { p })
        .ok_or_else(|| anyhow!("unknown matrix preset {matrix}"))?;
    let nodes = args.get_parsed("nodes", 4usize);
    let ppn = args.get_parsed("ppn", 8usize);
    let algo = args
        .get_with("algo", SddeAlgorithm::LocalityNonBlocking, parse_algo)
        .map_err(|e| anyhow!(e))?;
    let flavor = MpiFlavor::parse(args.get_or("mpi", "mvapich2"))
        .ok_or_else(|| anyhow!("unknown mpi flavor"))?;
    let variant = parse_variant(args, "v")?;
    let seed = args.get_parsed("seed", 2023u64);
    let faults = parse_faults(args)?;
    let out_path = PathBuf::from(args.get_or("out", "trace.json"));

    let topo = Topology::quartz(nodes, ppn);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);
    eprintln!(
        "tracing: matrix={} n={} ranks={} ({} nodes x {} ppn), algo={}, mpi={}",
        preset.name,
        preset.n,
        nranks,
        nodes,
        ppn,
        algo.name(),
        flavor.name()
    );
    let patterns: Rc<Vec<SpmvPattern>> = Rc::new(
        (0..nranks)
            .map(|r| SpmvPattern::build(&preset, part, r, seed))
            .collect(),
    );
    let run = RunSpec::new(topo, flavor)
        .algo(algo)
        .seed(seed)
        .faults(faults)
        .trace(TraceConfig::full())
        .run_sdde(variant, patterns);
    let (t, trace) = (run.time_ns, run.trace);
    if trace.events.is_empty() {
        bail!("trace recorded no events (tracing disabled?)");
    }
    let title = format!(
        "{} / {} / {} nodes x {} ppn ({})",
        preset.name,
        algo.name(),
        nodes,
        ppn,
        flavor.name()
    );
    println!("{}", trace.summary.render(&title));
    // --per-ctx (or any non-world traffic): per-context rollup with the
    // conservation verdict and cross-context delivery audit.
    let per_ctx = args.has("per-ctx");
    if per_ctx || trace.summary.has_multiple_ctx() {
        println!("{}", trace.summary.render_per_ctx());
    }
    println!();
    println!("{}", critical_path(&trace.events).render());
    println!("SDDE time (max over ranks): {}", fmt::ns(t));
    write_chrome_trace(&out_path, &trace.events)?;
    println!(
        "wrote {} ({} events; open in chrome://tracing or Perfetto)",
        out_path.display(),
        trace.events.len()
    );
    if let Some(csv) = args.get("csv") {
        let csv_path = PathBuf::from(csv);
        // --per-ctx forces the trailing ctx column even for world-only
        // traffic; otherwise it appears only when a derived context shows
        // up (single-comm exports stay byte-identical).
        if per_ctx {
            write_trace_csv_opts(&csv_path, &trace.events, true)?;
        } else {
            write_trace_csv(&csv_path, &trace.events)?;
        }
        println!("wrote {}", csv_path.display());
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let nx = args.get_parsed("nx", 48usize);
    let ny = args.get_parsed("ny", 48usize);
    let nodes = args.get_parsed("nodes", 2usize);
    let ppn = args.get_parsed("ppn", 4usize);
    let iters = args.get_parsed("iters", 300usize);
    let solver = args.get_or("solver", "cg").to_string();
    let algo = args
        .get_with("algo", SddeAlgorithm::LocalityNonBlocking, parse_algo)
        .map_err(|e| anyhow!(e))?;
    // Steady-state halo engine: persistent locality-aware by default; the
    // legacy per-message p2p path stays available as `--halo p2p`.
    let halo_method: Option<NeighborMethod> = match args.get_or("halo", "loc") {
        "p2p" | "legacy" => None,
        s => Some(
            NeighborMethod::parse(s).ok_or_else(|| anyhow!("unknown halo method {s}"))?,
        ),
    };

    let preset = MatrixPreset::poisson2d(nx, ny);
    let topo = Topology::quartz(nodes, ppn);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);
    eprintln!(
        "solving poisson2d {nx}x{ny} (n={}) on {} ranks with {} (pattern via {}, halo {})",
        preset.n,
        nranks,
        solver,
        algo.name(),
        halo_method.map(|m| m.name()).unwrap_or("p2p"),
    );
    let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
    let solver2 = solver.clone();
    let out = world.run(move |c| {
        let preset = MatrixPreset::poisson2d(nx, ny);
        let solver = solver2.clone();
        async move {
            let mx = MpixComm::new(c.clone(), RegionKind::Node);
            let info = MpixInfo::with_algorithm(algo);
            let pat = SpmvPattern::build(&preset, part, c.rank(), 0);
            let pkg = form_commpkg(&mx, &info, &pat).await.unwrap();
            let mut a = DistMatrix::build(&preset, part, c.rank(), 0, pkg);
            if let Some(method) = halo_method {
                a.init_halo(&mx, method).await;
            }
            let b = vec![1.0; a.local_n()];
            let kernel = CsrLocal(&a.local);
            let (_, hist) = match solver.as_str() {
                "jacobi" => jacobi(&c, &a, &b, &kernel, iters, 1.0).await,
                _ => cg(&c, &a, &b, &kernel, iters, 1e-10).await,
            };
            hist
        }
    });
    let hist = &out.results[0];
    println!("iterations: {}", hist.len());
    for (i, r) in hist.iter().enumerate() {
        if i % 10 == 0 || i + 1 == hist.len() {
            println!("  iter {i:>4}  residual {r:.3e}");
        }
    }
    println!(
        "virtual solve time: {}   total user msgs: {}",
        fmt::ns(out.end_time),
        out.counters.total_user_msgs()
    );
    Ok(())
}

/// Multi-pattern chaos (`chaos --patterns K`): K concurrent SDDEs in one
/// faulted world, each exchange on its own derived communicator. Checks
/// three things and fails loudly on each: every pattern's result matches
/// its serial single-pattern oracle, zero cross-context deliveries
/// occurred, and send↔recv conservation holds per context.
fn cmd_chaos_multi(args: &Args, patterns: usize) -> Result<()> {
    let matrix = args.get_or("matrix", "cage14");
    let div = args.get_parsed("div", 64usize);
    let preset = MatrixPreset::parse(matrix)
        .map(|p| if div > 1 { p.scaled(div) } else { p })
        .ok_or_else(|| anyhow!("unknown matrix preset {matrix}"))?;
    let nodes = args.get_parsed("nodes", 2usize);
    let ppn = args.get_parsed("ppn", 4usize);
    let algo = args
        .get_with("algo", SddeAlgorithm::Dispatch, parse_algo)
        .map_err(|e| anyhow!(e))?;
    let variant = parse_variant(args, "v")?;
    let seed = args.get_parsed("seed", 2023u64);
    let faults = parse_faults(args)?;
    let per_ctx = args.has("per-ctx");
    let csv = args.get("csv").map(PathBuf::from);
    let trace_cfg = if csv.is_some() {
        TraceConfig::full()
    } else {
        TraceConfig::counters_only()
    };

    let topo = Topology::quartz(nodes, ppn);
    let nranks = topo.nranks();
    let cfg = MultiConfig::new(topo, MpiFlavor::Mvapich2, patterns, preset)
        .algo(algo)
        .variant(variant)
        .seed(seed)
        .faults(faults)
        .trace(trace_cfg);
    eprintln!(
        "multi-pattern chaos: {} concurrent SDDEs on {} ranks ({} nodes x {} ppn), \
         algo {}, faults {}",
        patterns,
        nranks,
        nodes,
        ppn,
        algo.name(),
        match &faults {
            Some(p) => format!("seed {} ({})", p.seed, profile_label(&p.profile)),
            None => "off".to_string(),
        },
    );
    let run = run_multi(&cfg);
    let oracle = oracle_digests(&cfg);
    let agree = run.digests == oracle;
    println!(
        "-- multi-pattern chaos: {} pattern(s) x {} ranks --",
        patterns, nranks
    );
    println!("SDDE time (max over ranks): {}", fmt::ns(run.time_ns));
    println!("{}", run.trace.summary.render_per_ctx());
    println!(
        "oracle agreement: {}",
        if agree {
            "OK (every pattern matches its serial single-pattern run)"
        } else {
            "VIOLATED"
        }
    );
    if let Some(csv_path) = csv {
        write_trace_csv_opts(&csv_path, &run.trace.events, true)?;
        println!("wrote {}", csv_path.display());
    }
    let _ = per_ctx; // breakdown is always printed in multi-pattern mode
    if !agree {
        bail!("multi-pattern results diverged from serial oracles");
    }
    if run.trace.summary.cross_ctx_matches != 0 {
        bail!(
            "{} cross-context deliveries detected",
            run.trace.summary.cross_ctx_matches
        );
    }
    if !run.trace.summary.conservation_ok() {
        bail!("per-context send<->recv conservation violated");
    }
    Ok(())
}

/// Chaos sweep: one fault-free baseline plus one faulted re-run per seed,
/// reporting makespan inflation and enforcing the traffic invariant
/// (faults may move virtual time, never message counts).
fn cmd_chaos(args: &Args) -> Result<()> {
    if let Some(k) = args.get("patterns") {
        let k = parse_count(k).map_err(|e| anyhow!("bad --patterns {k}: {e}"))?;
        return cmd_chaos_multi(args, k);
    }
    let fig = {
        let s = args.get_or("fig", "5");
        FigureId::parse(s).ok_or_else(|| anyhow!("unknown figure {s}"))?
    };
    let div = args.get_parsed("div", 64usize);
    let mut base = SweepConfig::quick(fig, div);
    base.nodes = args
        .get_list_with("nodes", base.nodes, parse_count)
        .map_err(|e| anyhow!(e))?;
    base.ppn = args.get_parsed("ppn", base.ppn);
    base.seed = args.get_parsed("seed", base.seed);
    if let Some(ms) = args.get_list("matrices") {
        base.matrices = ms
            .iter()
            .map(|m| {
                MatrixPreset::parse(m)
                    .map(|p| if div > 1 { p.scaled(div) } else { p })
                    .ok_or_else(|| anyhow!("unknown matrix {m}"))
            })
            .collect::<Result<_>>()?;
    }
    base.jobs = resolve_jobs(args.get("jobs").and_then(|s| s.parse().ok()));
    // With a model loaded, run_chaos dispatches faulted re-runs under
    // this profile's noise regime and reports the resulting pick flips.
    base.dispatch = parse_dispatch(args, false)?;
    let seeds: Vec<u64> = match args.get_list("seeds") {
        Some(v) => v
            .iter()
            .map(|s| s.parse::<u64>().map_err(|_| anyhow!("bad seed {s}")))
            .collect::<Result<_>>()?,
        None => {
            let n = args.get_parsed("nseeds", 8u64);
            let s0 = args.get_parsed("seed0", 1u64);
            (s0..s0 + n).collect()
        }
    };
    let profile = {
        let s = args.get_or("profile", "heavy");
        FaultProfile::parse(s).map_err(|e| anyhow!("bad --profile {s}: {e}"))?
    };
    let rep = run_chaos(&ChaosConfig::new(base, seeds, profile));
    println!("{}", rep.render());
    if !rep.traffic_invariant() {
        bail!("traffic invariance violated under faults");
    }
    Ok(())
}

/// Print the dispatch layer's decision table for one pattern regime: the
/// calibrated model's pick per noise profile (with rationale and the full
/// score matrix), or the heuristic's pick when run with
/// `--dispatch-model none`.
fn cmd_dispatch(args: &Args) -> Result<()> {
    let matrix = args.get_or("matrix", "cage14");
    let div = args.get_parsed("div", 16usize);
    let preset = MatrixPreset::parse(matrix)
        .map(|p| if div > 1 { p.scaled(div) } else { p })
        .ok_or_else(|| anyhow!("unknown matrix preset {matrix}"))?;
    let nodes = args.get_parsed("nodes", 4usize);
    let ppn = args.get_parsed("ppn", 8usize);
    let variant = parse_variant(args, "v")?;
    let seed = args.get_parsed("seed", 2023u64);
    let region = match args.get("region") {
        None => RegionKind::Node,
        Some(r) => RegionKind::parse(r).ok_or_else(|| anyhow!("unknown region {r}"))?,
    };
    let model = parse_dispatch(args, true)?;

    let topo = Topology::quartz(nodes, ppn);
    let nranks = topo.nranks();
    let part = Partition::new(preset.n, nranks);
    let patterns: Vec<SpmvPattern> = (0..nranks)
        .map(|r| SpmvPattern::build(&preset, part, r, seed))
        .collect();
    let stats = pattern_set_stats(&topo, region, variant, &patterns);
    println!(
        "pattern: {} on {} ranks ({} nodes x {} ppn) — mean dests/rank {}, \
         local frac {:.2}, bucket {}",
        preset.name,
        nranks,
        nodes,
        ppn,
        stats.send_nnz,
        stats.local_frac,
        stats.bucket()
    );
    match &model {
        Some(m) => {
            println!("{}", m.summary_table());
            println!("{}", m.decision_table(&stats));
        }
        None => {
            let sel = dispatch::select(None, &stats, parse_noise(args).as_deref());
            println!("no model loaded; {}", sel.rationale);
            println!("pick: {}", sel.algo.name());
        }
    }

    if args.has("split") {
        // Same decision re-run on a node-parity split communicator: the
        // region map, pattern stats, and dispatch pick are all computed
        // comm-locally, proving the dispatch layer works on derived
        // communicators (and exercising Comm::split end to end).
        let topo = Topology::quartz(nodes, ppn);
        let preset2 = preset.clone();
        let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let preset = preset2.clone();
            async move {
                let color = (c.rank() / ppn) % 2;
                let sub = c.split(color as u64, c.rank() as i64).await;
                if color != 0 || sub.rank() != 0 {
                    return None;
                }
                let n = sub.nranks();
                let ctx = sub.ctx().0;
                let mx = MpixComm::new(sub, region);
                let part = Partition::new(preset.n, n);
                let pats: Vec<SpmvPattern> = (0..n)
                    .map(|r| SpmvPattern::build(&preset, part, r, seed))
                    .collect();
                Some((pattern_set_stats_for(&mx, variant, &pats), ctx, n))
            }
        });
        let (split_stats, ctx, sub_n) = out
            .results
            .into_iter()
            .flatten()
            .next()
            .expect("color 0 is never empty");
        println!(
            "split comm: {} of {} ranks on ctx {} — bucket {}",
            sub_n,
            nranks,
            ctx,
            split_stats.bucket()
        );
        match &model {
            Some(m) => println!("{}", m.decision_table(&split_stats)),
            None => {
                let sel = dispatch::select(None, &split_stats, parse_noise(args).as_deref());
                println!("pick on split comm: {}", sel.algo.name());
            }
        }
    }
    Ok(())
}

/// Calibrate a dispatch model from figure + chaos sweeps and print it.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let mut cfg = CalibrateConfig::quick();
    cfg.figs = match args.get_or("figs", "5,7") {
        "all" => vec![FigureId::Fig5, FigureId::Fig6, FigureId::Fig7, FigureId::Fig8],
        _ => args
            .get_list("figs")
            .unwrap_or_else(|| vec!["5".into(), "7".into()])
            .iter()
            .map(|s| FigureId::parse(s).ok_or_else(|| anyhow!("unknown figure {s}")))
            .collect::<Result<_>>()?,
    };
    cfg.div = args.get_parsed("div", cfg.div);
    cfg.nodes = args
        .get_list_with("nodes", cfg.nodes, parse_count)
        .map_err(|e| anyhow!(e))?;
    cfg.ppn = args.get_parsed("ppn", cfg.ppn);
    if let Some(ms) = args.get_list("matrices") {
        let div = cfg.div;
        cfg.matrices = Some(
            ms.iter()
                .map(|m| {
                    MatrixPreset::parse(m)
                        .map(|p| if div > 1 { p.scaled(div) } else { p })
                        .ok_or_else(|| anyhow!("unknown matrix {m}"))
                })
                .collect::<Result<_>>()?,
        );
    }
    if let Some(ps) = args.get_list("profiles") {
        cfg.profiles = ps;
    }
    cfg.seeds = match args.get_list("seeds") {
        Some(v) => v
            .iter()
            .map(|s| s.parse::<u64>().map_err(|_| anyhow!("bad seed {s}")))
            .collect::<Result<_>>()?,
        None => {
            let n = args.get_parsed("nseeds", cfg.seeds.len() as u64);
            let s0 = args.get_parsed("seed0", 1u64);
            (s0..s0 + n).collect()
        }
    };
    cfg.robustness = args.get_parsed("robustness", cfg.robustness);
    cfg.jobs = resolve_jobs(args.get("jobs").and_then(|s| s.parse().ok()));
    cfg.progress = if args.has("quiet") {
        ProgressSink::Silent
    } else {
        ProgressSink::Stderr
    };

    eprintln!(
        "calibrating over {} figure(s), nodes {:?}, ppn {}, {} profile(s) x {} seed(s)...",
        cfg.figs.len(),
        cfg.nodes,
        cfg.ppn,
        cfg.profiles.len(),
        cfg.seeds.len()
    );
    let model = run_calibrate(&cfg)?;
    println!("{}", model.summary_table());
    if let Some(out) = args.get("out") {
        let path = PathBuf::from(out);
        model.save(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("matrix presets (paper set):");
    for p in MatrixPreset::paper_set() {
        println!(
            "  {:<24} n={:<9} ~nnz={:<10} kind={:?}",
            p.name,
            p.n,
            p.approx_nnz(),
            p.kind
        );
    }
    println!("\nalgorithms (+ loc-rma extension, const-size only):");
    for a in SddeAlgorithm::CONST_SIZE {
        println!("  {}", a.name());
    }
    println!("  dispatch (evidence-driven selection; see `sdde dispatch`)");
    println!("\nmpi flavors: openmpi, mvapich2");
    for f in [MpiFlavor::OpenMpi, MpiFlavor::Mvapich2] {
        let c = CostModel::preset(f);
        println!(
            "  {:<9} latency[self,socket,xsocket,node]={:?} ns, eager={}B, match={}+{}n ns",
            f.name(),
            c.latency,
            c.eager_limit,
            c.match_base,
            c.match_per_entry
        );
    }
    Ok(())
}
