//! Row-wise block partition (paper §II-A): `n` rows split contiguously
//! across `nparts` processes; the first `n % nparts` parts hold one extra
//! row. Owner lookup is O(1).

/// Contiguous row-block partition of `n` rows over `nparts` parts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    pub n: usize,
    pub nparts: usize,
}

impl Partition {
    pub fn new(n: usize, nparts: usize) -> Partition {
        assert!(nparts >= 1);
        Partition { n, nparts }
    }

    /// Rows held by part `p`.
    pub fn size(&self, p: usize) -> usize {
        self.n / self.nparts + usize::from(p < self.n % self.nparts)
    }

    /// First global row of part `p`.
    pub fn start(&self, p: usize) -> usize {
        let q = self.n / self.nparts;
        let r = self.n % self.nparts;
        p * q + p.min(r)
    }

    /// Global row range `[start, end)` of part `p`.
    pub fn range(&self, p: usize) -> (usize, usize) {
        (self.start(p), self.start(p) + self.size(p))
    }

    /// Owner of global row `i`.
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        let q = self.n / self.nparts;
        let r = self.n % self.nparts;
        let cut = r * (q + 1);
        if q == 0 {
            // more parts than rows: rows 0..r map 1:1, rest are empty
            i
        } else if i < cut {
            i / (q + 1)
        } else {
            r + (i - cut) / q
        }
    }

    /// Local index of global row `i` within its owner.
    pub fn to_local(&self, i: usize) -> usize {
        i - self.start(self.owner(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        let p = Partition::new(12, 4);
        for q in 0..4 {
            assert_eq!(p.size(q), 3);
            assert_eq!(p.range(q), (q * 3, q * 3 + 3));
        }
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(11), 3);
    }

    #[test]
    fn uneven_split_consistent() {
        for (n, parts) in [(13usize, 4usize), (7, 3), (100, 7), (5, 8), (1, 1)] {
            let p = Partition::new(n, parts);
            // sizes sum to n, ranges tile [0, n)
            let total: usize = (0..parts).map(|q| p.size(q)).sum();
            assert_eq!(total, n, "n={n} parts={parts}");
            let mut next = 0;
            for q in 0..parts {
                let (s, e) = p.range(q);
                assert_eq!(s, next);
                next = e;
            }
            assert_eq!(next, n);
            // owner agrees with ranges
            for i in 0..n {
                let o = p.owner(i);
                let (s, e) = p.range(o);
                assert!(s <= i && i < e, "row {i} owner {o} range ({s},{e})");
                assert_eq!(p.to_local(i), i - s);
            }
        }
    }

    #[test]
    fn more_parts_than_rows() {
        let p = Partition::new(3, 5);
        assert_eq!(p.size(0), 1);
        assert_eq!(p.size(3), 0);
        assert_eq!(p.owner(2), 2);
    }
}
