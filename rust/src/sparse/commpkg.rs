//! Communication-package formation — the paper's motivating SDDE use case
//! (§II): every rank derives *what it must receive* from its local sparsity
//! (off-process columns grouped by owner), then an `MPIX_Alltoallv_crs`
//! discovers *what it must send* (the transpose). The resulting
//! [`CommPkg`] drives every subsequent SpMV halo exchange.

use std::collections::BTreeMap;

use anyhow::Result;

use super::gen::MatrixPreset;
use super::partition::Partition;
use crate::mpix::{
    alltoall_crs, alltoallv_crs, CrsArgs, CrsvArgs, MpixComm, MpixInfo, NeighborComm,
    PatternStats,
};
use crate::simnet::{RegionKind, Topology};

/// Per-rank receive requirements: for each owner rank, the sorted global
/// columns this rank needs from it. This is the *known* half of the
/// pattern (and the SDDE's send side: we send our index requests to the
/// owners).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SpmvPattern {
    pub rank: usize,
    /// (owner, sorted global columns), ascending by owner; never contains
    /// the rank itself.
    pub needed: Vec<(usize, Vec<usize>)>,
}

impl SpmvPattern {
    /// Build from the row-deterministic generator without materializing
    /// values (the figure-sweep fast path).
    pub fn build(preset: &MatrixPreset, part: Partition, rank: usize, seed: u64) -> SpmvPattern {
        let (start, end) = part.range(rank);
        let mut off: Vec<usize> = Vec::new();
        let mut row_buf: Vec<usize> = Vec::new();
        for row in start..end {
            preset.row_cols_into(row, seed, &mut row_buf);
            for &c in &row_buf {
                if c < start || c >= end {
                    off.push(c);
                }
            }
        }
        off.sort_unstable();
        off.dedup();
        Self::from_columns(part, rank, &off)
    }

    /// Build from an explicit off-process column set.
    pub fn from_columns(part: Partition, rank: usize, off_cols: &[usize]) -> SpmvPattern {
        // Fast path (§Perf): for a contiguous row partition, owners are
        // monotone in the column index, so sorted input groups by simple
        // boundary detection — no map lookups.
        if off_cols.windows(2).all(|w| w[0] < w[1]) {
            let mut needed: Vec<(usize, Vec<usize>)> = Vec::new();
            let mut i = 0;
            while i < off_cols.len() {
                let o = part.owner(off_cols[i]);
                debug_assert_ne!(o, rank, "column {} is local", off_cols[i]);
                let (_, oe) = part.range(o);
                let j = i + off_cols[i..].partition_point(|&c| c < oe);
                needed.push((o, off_cols[i..j].to_vec()));
                i = j;
            }
            return SpmvPattern { rank, needed };
        }
        let mut by_owner: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &c in off_cols {
            let o = part.owner(c);
            debug_assert_ne!(o, rank, "column {c} is local");
            by_owner.entry(o).or_default().push(c);
        }
        SpmvPattern {
            rank,
            needed: by_owner.into_iter().collect(),
        }
    }

    /// Number of neighbor ranks this rank receives from.
    pub fn recv_nnz(&self) -> usize {
        self.needed.len()
    }

    /// Total off-process columns needed.
    pub fn recv_size(&self) -> usize {
        self.needed.iter().map(|(_, c)| c.len()).sum()
    }

    /// SDDE send side for `MPIX_Alltoallv_crs`: request lists (the indices
    /// we need) addressed to their owners.
    pub fn crsv_args(&self) -> CrsvArgs {
        CrsvArgs {
            dest: self.needed.iter().map(|&(o, _)| o).collect(),
            sendcounts: self.needed.iter().map(|(_, c)| c.len()).collect(),
            sendvals: self
                .needed
                .iter()
                .flat_map(|(_, c)| c.iter().map(|&x| x as u64))
                .collect(),
        }
    }

    /// The dispatch-layer view of this rank's SDDE regime: exactly what
    /// [`PatternStats::measure`] computes inside `alltoall(v)_crs`, but
    /// available before any world exists — so sweeps and the CLI can
    /// report (or pre-compute) the pick for a pattern without running it.
    pub fn dispatch_stats(
        &self,
        topo: &Topology,
        region: RegionKind,
        constant: bool,
    ) -> PatternStats {
        let me = topo.region_of(self.rank, region);
        let local = self
            .needed
            .iter()
            .filter(|(o, _)| topo.region_of(*o, region) == me)
            .count();
        PatternStats {
            nranks: topo.nranks(),
            region_size: topo.region_size(self.rank, region),
            send_nnz: self.needed.len(),
            local_frac: if self.needed.is_empty() {
                0.0
            } else {
                local as f64 / self.needed.len() as f64
            },
            constant,
        }
    }

    /// SDDE send side for `MPIX_Alltoall_crs`: one integer per owner — the
    /// number of elements we will pull in later exchanges (the paper's
    /// Fig. 5/6 workload).
    pub fn crs_size_args(&self) -> CrsArgs {
        CrsArgs {
            dest: self.needed.iter().map(|&(o, _)| o).collect(),
            sendcount: 1,
            sendvals: self.needed.iter().map(|(_, c)| c.len() as u64).collect(),
        }
    }
}

/// The formed communication pattern: both halves of the SpMV halo
/// exchange for one rank.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommPkg {
    /// (owner, global columns) this rank receives each SpMV — known a
    /// priori from the local sparsity.
    pub recv_from: Vec<(usize, Vec<usize>)>,
    /// (neighbor, global rows) this rank must send each SpMV — discovered
    /// by the SDDE.
    pub send_to: Vec<(usize, Vec<usize>)>,
}

impl CommPkg {
    pub fn send_size(&self) -> usize {
        self.send_to.iter().map(|(_, v)| v.len()).sum()
    }
    pub fn recv_size(&self) -> usize {
        self.recv_from.iter().map(|(_, v)| v.len()).sum()
    }
}

/// Form the full communication package via the variable-size SDDE
/// (`MPIX_Alltoallv_crs`) — the Hypre/BoomerAMG-style use (paper §III).
pub async fn form_commpkg(
    mx: &MpixComm,
    info: &MpixInfo,
    pattern: &SpmvPattern,
) -> Result<CommPkg> {
    let res = alltoallv_crs(mx, info, &pattern.crsv_args()).await?;
    let send_to = res
        .src
        .iter()
        .enumerate()
        .map(|(i, &s)| (s, res.vals(i).iter().map(|&x| x as usize).collect()))
        .collect();
    Ok(CommPkg {
        recv_from: pattern.needed.clone(),
        send_to,
    })
}

/// Form the communication package *and* hand back a ready-to-use
/// [`NeighborComm`] over it — the one-call path from "local sparsity" to
/// "steady-state neighborhood collective" (pattern formation via the SDDE,
/// pattern use via `mpix::neighbor`).
pub async fn form_neighborhood(
    mx: &MpixComm,
    info: &MpixInfo,
    pattern: &SpmvPattern,
) -> Result<(CommPkg, NeighborComm)> {
    let pkg = form_commpkg(mx, info, pattern).await?;
    let nc = NeighborComm::from_commpkg(mx, &pkg);
    Ok((pkg, nc))
}

/// Form only the receive *sizes* via the constant-size SDDE
/// (`MPIX_Alltoall_crs`) — the CELLAR-style use (paper §III): returns
/// (neighbor, element-count) pairs for the messages this rank will send in
/// later exchanges.
pub async fn form_commpkg_sizes(
    mx: &MpixComm,
    info: &MpixInfo,
    pattern: &SpmvPattern,
) -> Result<Vec<(usize, u64)>> {
    let res = alltoall_crs(mx, info, &pattern.crs_size_args()).await?;
    Ok(res
        .src
        .iter()
        .zip(res.recvvals.iter())
        .map(|(&s, &v)| (s, v))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::mpix::SddeAlgorithm;
    use crate::simnet::{CostModel, MpiFlavor, RegionKind, Topology};
    use std::rc::Rc;

    #[test]
    fn pattern_paper_example() {
        // Figure 1's 4×4 matrix over 4 processes (1 row each):
        //   row 0: cols {0, 1}
        //   row 1: cols {1, 3}
        //   row 2: cols {0, 2, 3}
        //   row 3: cols {1, 3}
        let part = Partition::new(4, 4);
        let rows: [&[usize]; 4] = [&[0, 1], &[1, 3], &[0, 2, 3], &[1, 3]];
        let pats: Vec<SpmvPattern> = (0..4)
            .map(|p| {
                let off: Vec<usize> = rows[p].iter().copied().filter(|&c| c != p).collect();
                SpmvPattern::from_columns(part, p, &off)
            })
            .collect();
        // P2 needs v0 and v3 (paper §II-B).
        assert_eq!(pats[2].needed, vec![(0, vec![0]), (3, vec![3])]);
        assert_eq!(pats[0].needed, vec![(1, vec![1])]);
    }

    #[test]
    fn build_matches_generator() {
        let preset = MatrixPreset::fault_639_like().scaled(2000);
        let part = Partition::new(preset.n, 8);
        let pat = SpmvPattern::build(&preset, part, 3, 11);
        // every needed column really appears in some local row and is off-proc
        let (s, e) = part.range(3);
        let mut all_off: Vec<usize> = Vec::new();
        for row in s..e {
            for c in preset.row_cols(row, 11) {
                if c < s || c >= e {
                    all_off.push(c);
                }
            }
        }
        all_off.sort_unstable();
        all_off.dedup();
        let from_pat: Vec<usize> = pat
            .needed
            .iter()
            .flat_map(|(_, c)| c.iter().copied())
            .collect();
        assert_eq!(from_pat, all_off);
        for (o, cols) in &pat.needed {
            for &c in cols {
                assert_eq!(part.owner(c), *o);
            }
        }
    }

    #[test]
    fn commpkg_duality_all_algorithms() {
        // The formed send side must be the exact transpose of the receive
        // side, for every SDDE algorithm.
        let preset = MatrixPreset::cage14_like().scaled(4000);
        let topo = Topology::quartz(2, 4);
        let n = topo.nranks();
        let part = Partition::new(preset.n, n);
        let pats: Vec<SpmvPattern> = (0..n)
            .map(|p| SpmvPattern::build(&preset, part, p, 5))
            .collect();
        let pats = Rc::new(pats);
        for algo in SddeAlgorithm::VARIABLE {
            let pats2 = pats.clone();
            let world = World::new(topo.clone(), CostModel::preset(MpiFlavor::Mvapich2));
            let out = world.run(move |c| {
                let pats = pats2.clone();
                async move {
                    let mx = MpixComm::new(c.clone(), RegionKind::Node);
                    let info = MpixInfo::with_algorithm(algo);
                    form_commpkg(&mx, &info, &pats[c.rank()]).await.unwrap()
                }
            });
            // transpose check
            for p in 0..n {
                for (owner, cols) in &out.results[p].recv_from {
                    let back = out.results[*owner]
                        .send_to
                        .iter()
                        .find(|(r, _)| r == &p)
                        .unwrap_or_else(|| panic!("algo {algo:?}: {owner} missing send to {p}"));
                    assert_eq!(&back.1, cols, "algo {algo:?}: {owner}->{p}");
                }
                let total_sends: usize = out.results[p].send_to.len();
                let expected: usize = (0..n)
                    .filter(|&q| {
                        out.results[q]
                            .recv_from
                            .iter()
                            .any(|(o, _)| *o == p)
                    })
                    .count();
                assert_eq!(total_sends, expected, "algo {algo:?} rank {p}");
            }
        }
    }

    #[test]
    fn dispatch_stats_match_in_world_measurement() {
        // The offline (no-world) stats must be exactly what the dispatch
        // layer measures inside the SDDE call — same pick, same bucket.
        let preset = MatrixPreset::cage14_like().scaled(2000);
        let topo = Topology::quartz(2, 4);
        let n = topo.nranks();
        let part = Partition::new(preset.n, n);
        let pats: Vec<SpmvPattern> = (0..n)
            .map(|p| SpmvPattern::build(&preset, part, p, 5))
            .collect();
        let offline: Vec<PatternStats> = pats
            .iter()
            .map(|p| p.dispatch_stats(&topo, RegionKind::Node, false))
            .collect();
        let pats = Rc::new(pats);
        let world = World::new(topo, CostModel::preset(MpiFlavor::Mvapich2));
        let out = world.run(move |c| {
            let pats = pats.clone();
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let dest = pats[c.rank()].crsv_args().dest;
                PatternStats::measure(&mx, &dest, false)
            }
        });
        assert_eq!(out.results, offline);
    }

    #[test]
    fn commpkg_sizes_matches_full() {
        let preset = MatrixPreset::dielfilterv2clx_like().scaled(1000);
        let topo = Topology::quartz(2, 3);
        let n = topo.nranks();
        let part = Partition::new(preset.n, n);
        let pats: Vec<SpmvPattern> = (0..n)
            .map(|p| SpmvPattern::build(&preset, part, p, 9))
            .collect();
        let pats = Rc::new(pats);
        let world = World::new(topo, CostModel::preset(MpiFlavor::OpenMpi));
        let out = world.run(move |c| {
            let pats = pats.clone();
            async move {
                let mx = MpixComm::new(c.clone(), RegionKind::Node);
                let info = MpixInfo::with_algorithm(SddeAlgorithm::Personalized);
                let full = form_commpkg(&mx, &info, &pats[c.rank()]).await.unwrap();
                let sizes = form_commpkg_sizes(&mx, &info, &pats[c.rank()])
                    .await
                    .unwrap();
                (full, sizes)
            }
        });
        for (full, sizes) in &out.results {
            let from_full: Vec<(usize, u64)> = full
                .send_to
                .iter()
                .map(|(r, v)| (*r, v.len() as u64))
                .collect();
            assert_eq!(&from_full, sizes);
        }
    }
}
