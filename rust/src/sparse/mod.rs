//! Sparse-matrix substrate: storage ([`csr`]), row-wise partitioning
//! ([`partition`]), synthetic SuiteSparse analogs ([`gen`]), MatrixMarket
//! I/O ([`mm`]) and SDDE-driven communication-package formation
//! ([`commpkg`]) — the paper's motivating use case (§II).

pub mod commpkg;
pub mod csr;
pub mod gen;
pub mod mm;
pub mod partition;

pub use commpkg::{form_commpkg, form_commpkg_sizes, form_neighborhood, CommPkg, SpmvPattern};
pub use csr::{BlockEll, CsrMatrix};
pub use gen::MatrixPreset;
pub use partition::Partition;
