//! CSR storage and the Block-ELL layout the Pallas kernel consumes.

/// Compressed sparse row matrix (f64 values).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<usize>,
    pub cols: Vec<usize>,
    pub vals: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-row (col, val) lists (cols need not be sorted).
    pub fn from_rows(nrows: usize, ncols: usize, rows: Vec<Vec<(usize, f64)>>) -> CsrMatrix {
        assert_eq!(rows.len(), nrows);
        let mut rowptr = Vec::with_capacity(nrows + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        rowptr.push(0);
        for mut row in rows {
            row.sort_by_key(|&(c, _)| c);
            for (c, v) in row {
                debug_assert!(c < ncols);
                cols.push(c);
                vals.push(v);
            }
            rowptr.push(cols.len());
        }
        CsrMatrix {
            nrows,
            ncols,
            rowptr,
            cols,
            vals,
        }
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Columns of row `r`.
    pub fn row_cols(&self, r: usize) -> &[usize] {
        &self.cols[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Values of row `r`.
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.vals[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Dense sequential SpMV (reference for tests): `y = A x`.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for r in 0..self.nrows {
            let mut acc = 0.0;
            for k in self.rowptr[r]..self.rowptr[r + 1] {
                acc += self.vals[k] * x[self.cols[k]];
            }
            y[r] = acc;
        }
        y
    }

    /// Maximum row degree.
    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows)
            .map(|r| self.rowptr[r + 1] - self.rowptr[r])
            .max()
            .unwrap_or(0)
    }

    /// Convert to the padded Block-ELL layout consumed by the Pallas/XLA
    /// kernel: `rows_pad × width` dense arrays of values and column
    /// indices, rows padded to a multiple of `row_tile` and entries padded
    /// with (col 0, val 0). `x` must also be padded so index 0 is valid.
    pub fn to_block_ell(&self, row_tile: usize, width: usize) -> BlockEll {
        assert!(width >= self.max_row_nnz(), "ELL width too small");
        let rows_pad = self.nrows.div_ceil(row_tile).max(1) * row_tile;
        let mut vals = vec![0.0f32; rows_pad * width];
        let mut cols = vec![0i32; rows_pad * width];
        for r in 0..self.nrows {
            let (s, e) = (self.rowptr[r], self.rowptr[r + 1]);
            for (j, k) in (s..e).enumerate() {
                vals[r * width + j] = self.vals[k] as f32;
                cols[r * width + j] = self.cols[k] as i32;
            }
        }
        BlockEll {
            nrows: self.nrows,
            rows_pad,
            width,
            ncols: self.ncols,
            vals,
            cols,
        }
    }
}

/// Padded ELL layout with row-tile alignment (see
/// `python/compile/kernels/spmv.py` — identical semantics: the kernel
/// computes `y[i] = Σ_j vals[i,j] · x[cols[i,j]]`).
#[derive(Clone, Debug)]
pub struct BlockEll {
    pub nrows: usize,
    pub rows_pad: usize,
    pub width: usize,
    pub ncols: usize,
    /// Row-major `rows_pad × width` (f32 — the XLA artifact's dtype).
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
}

impl BlockEll {
    /// Reference SpMV on the ELL layout (f32; oracle for the XLA artifact).
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert!(x.len() >= self.ncols);
        let mut y = vec![0.0f32; self.rows_pad];
        for r in 0..self.rows_pad {
            let mut acc = 0.0f32;
            for j in 0..self.width {
                acc += self.vals[r * self.width + j] * x[self.cols[r * self.width + j] as usize];
            }
            y[r] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix {
        // [2 0 1]
        // [0 3 0]
        // [4 0 5]
        CsrMatrix::from_rows(
            3,
            3,
            vec![
                vec![(0, 2.0), (2, 1.0)],
                vec![(1, 3.0)],
                vec![(2, 5.0), (0, 4.0)],
            ],
        )
    }

    #[test]
    fn csr_layout() {
        let a = small();
        assert_eq!(a.nnz(), 5);
        assert_eq!(a.rowptr, vec![0, 2, 3, 5]);
        assert_eq!(a.row_cols(2), &[0, 2]); // sorted
        assert_eq!(a.row_vals(2), &[4.0, 5.0]);
    }

    #[test]
    fn spmv_reference() {
        let a = small();
        let y = a.spmv(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![5.0, 6.0, 19.0]);
    }

    #[test]
    fn block_ell_round_trip() {
        let a = small();
        let ell = a.to_block_ell(4, 2);
        assert_eq!(ell.rows_pad, 4);
        assert_eq!(ell.width, 2);
        let x = [1.0f32, 2.0, 3.0];
        let y = ell.spmv_ref(&x);
        assert_eq!(&y[..3], &[5.0, 6.0, 19.0]);
        assert_eq!(y[3], 0.0); // padded row
    }

    #[test]
    #[should_panic(expected = "ELL width too small")]
    fn block_ell_width_checked() {
        small().to_block_ell(4, 1);
    }
}
