//! Synthetic matrix generators, including analogs of the paper's four
//! SuiteSparse matrices (nnz ≈ 25 M; see DESIGN.md §Substitutions).
//!
//! Generation is *row-deterministic*: the columns of global row `r` depend
//! only on `(preset, seed, r)`, so any rank can generate exactly its own
//! rows (or just their sparsity) without materializing the global matrix —
//! this keeps the 2048-rank figure sweeps cheap.
//!
//! The four analogs are calibrated to the communication regimes the paper
//! exploits:
//! * `dielfilterv2clx_like` — tight FEM band → *fewest* messages/rank
//!   (the matrix where locality-aware aggregation loses, Fig. 7–8);
//! * `fault_639_like` — band + contact clusters → moderate counts;
//! * `curlcurl_4_like` — wide multi-band edge elements → moderate-high;
//! * `cage14_like` — scattered long-range couplings → *highest* counts
//!   (the 20×-speedup regime).

use super::csr::CsrMatrix;
use crate::util::Rng;

/// Sparsity-structure family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Gaussian band around the diagonal.
    Band,
    /// Band plus occasional far "contact" clusters.
    BandCluster,
    /// Superposition of three bands of increasing width.
    MultiBand,
    /// Band plus a fraction of uniformly scattered columns.
    Scattered,
    /// Exact 5-point Poisson stencil on an nx × ny grid (SPD; solver tests).
    Poisson2D,
    /// Fully uniform random columns.
    Uniform,
}

/// A reproducible matrix description. See module docs; constructors below.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixPreset {
    pub name: String,
    pub kind: Kind,
    /// Dimension (rows == cols). For Poisson2D this is nx·ny.
    pub n: usize,
    /// Mean row degree (ignored by Poisson2D).
    pub deg: usize,
    /// Band standard deviation in columns (Band-ish kinds); nx for Poisson2D.
    pub band: usize,
    /// Percent of entries drawn uniformly at random (Scattered).
    pub far_pct: u8,
}

impl MatrixPreset {
    /// dielFilterV2clx: n=607,232, 25.3M nnz, high-order FEM, narrow
    /// coupling → lowest message count of the set (paper §V).
    pub fn dielfilterv2clx_like() -> MatrixPreset {
        MatrixPreset {
            name: "dielfilterv2clx_like".into(),
            kind: Kind::Band,
            n: 607_232,
            deg: 42,
            band: 900,
            far_pct: 0,
        }
    }

    /// Fault_639: n=638,802, 28.6M nnz, solid mechanics with contact.
    pub fn fault_639_like() -> MatrixPreset {
        MatrixPreset {
            name: "fault_639_like".into(),
            kind: Kind::BandCluster,
            n: 638_802,
            deg: 45,
            band: 3_500,
            far_pct: 0,
        }
    }

    /// CurlCurl_4: n=2,380,515, 26.5M nnz, edge elements, wide stencil.
    pub fn curlcurl_4_like() -> MatrixPreset {
        MatrixPreset {
            name: "curlcurl_4_like".into(),
            kind: Kind::MultiBand,
            n: 2_380_515,
            deg: 11,
            band: 2_500,
            far_pct: 0,
        }
    }

    /// cage14: n=1,505,785, 27.1M nnz, DNA electrophoresis transition
    /// graph — scattered couplings, the highest message counts.
    pub fn cage14_like() -> MatrixPreset {
        MatrixPreset {
            name: "cage14_like".into(),
            kind: Kind::Scattered,
            n: 1_505_785,
            deg: 18,
            band: 15_000,
            far_pct: 20,
        }
    }

    /// The paper's evaluation set (§V).
    pub fn paper_set() -> Vec<MatrixPreset> {
        vec![
            MatrixPreset::dielfilterv2clx_like(),
            MatrixPreset::fault_639_like(),
            MatrixPreset::curlcurl_4_like(),
            MatrixPreset::cage14_like(),
        ]
    }

    /// 5-point Poisson stencil on an `nx × ny` grid (SPD — CG converges).
    pub fn poisson2d(nx: usize, ny: usize) -> MatrixPreset {
        MatrixPreset {
            name: format!("poisson2d_{nx}x{ny}"),
            kind: Kind::Poisson2D,
            n: nx * ny,
            deg: 5,
            band: nx,
            far_pct: 0,
        }
    }

    pub fn banded(n: usize, deg: usize, band: usize) -> MatrixPreset {
        MatrixPreset {
            name: format!("banded_n{n}_d{deg}_b{band}"),
            kind: Kind::Band,
            n,
            deg,
            band,
            far_pct: 0,
        }
    }

    pub fn uniform(n: usize, deg: usize) -> MatrixPreset {
        MatrixPreset {
            name: format!("uniform_n{n}_d{deg}"),
            kind: Kind::Uniform,
            n,
            deg,
            band: 0,
            far_pct: 100,
        }
    }

    pub fn parse(s: &str) -> Option<MatrixPreset> {
        match s {
            "dielfilterv2clx" | "dielfilterv2clx_like" => {
                Some(MatrixPreset::dielfilterv2clx_like())
            }
            "fault_639" | "fault_639_like" => Some(MatrixPreset::fault_639_like()),
            "curlcurl_4" | "curlcurl_4_like" => Some(MatrixPreset::curlcurl_4_like()),
            "cage14" | "cage14_like" => Some(MatrixPreset::cage14_like()),
            _ => None,
        }
    }

    /// Shrink the problem by `div` (n and band scale down, degree kept):
    /// preserves the per-rank communication character at smaller scales —
    /// used by tests and the quick bench mode.
    pub fn scaled(&self, div: usize) -> MatrixPreset {
        assert!(div >= 1);
        if self.kind == Kind::Poisson2D {
            let nx = (self.band / div).max(2);
            let ny = (self.n / self.band / div).max(2);
            return MatrixPreset::poisson2d(nx, ny);
        }
        MatrixPreset {
            name: format!("{}_div{div}", self.name),
            n: (self.n / div).max(16),
            band: (self.band / div).max(2),
            ..self.clone()
        }
    }

    /// Approximate nnz (n · deg).
    pub fn approx_nnz(&self) -> usize {
        self.n * self.deg
    }

    fn row_rng(&self, row: usize, seed: u64) -> Rng {
        let mut h = seed;
        for b in self.name.bytes() {
            h = h.wrapping_mul(0x100000001B3).wrapping_add(b as u64);
        }
        Rng::stream(h, row as u64)
    }

    /// Sorted, deduplicated columns of global row `row` (always includes
    /// the diagonal).
    pub fn row_cols(&self, row: usize, seed: u64) -> Vec<usize> {
        let mut cols = Vec::new();
        self.row_cols_into(row, seed, &mut cols);
        cols
    }

    /// Like [`MatrixPreset::row_cols`] but reusing `cols` (§Perf: the
    /// pattern builder calls this once per row — no per-row allocation).
    pub fn row_cols_into(&self, row: usize, seed: u64, cols: &mut Vec<usize>) {
        cols.clear();
        let n = self.n as i64;
        let r = row as i64;
        match self.kind {
            Kind::Poisson2D => {
                let nx = self.band as i64;
                let (x, y) = (r % nx, r / nx);
                let ny = n / nx;
                cols.push(row);
                if x > 0 {
                    cols.push((r - 1) as usize);
                }
                if x + 1 < nx {
                    cols.push((r + 1) as usize);
                }
                if y > 0 {
                    cols.push((r - nx) as usize);
                }
                if y + 1 < ny {
                    cols.push((r + nx) as usize);
                }
            }
            _ => {
                let mut rng = self.row_rng(row, seed);
                let jitter = (self.deg / 4).max(1) as i64;
                let deg = (self.deg as i64 + rng.range(-jitter, jitter + 1)).max(2) as usize;
                cols.push(row);
                for _ in 0..deg - 1 {
                    let c = match self.kind {
                        Kind::Band => band_col(&mut rng, r, self.band as f64, n),
                        Kind::BandCluster => {
                            if rng.chance(0.08) {
                                // contact cluster: each row couples to one
                                // persistent far block (structural, so
                                // nearby rows share owners)
                                let center = cluster_center(self, row, 0, n);
                                band_col(&mut rng, center, 24.0, n)
                            } else {
                                band_col(&mut rng, r, self.band as f64, n)
                            }
                        }
                        Kind::MultiBand => {
                            let sigma = match rng.below(20) {
                                0..=13 => self.band as f64,
                                14..=17 => self.band as f64 * 12.0,
                                _ => self.band as f64 * 40.0,
                            };
                            band_col(&mut rng, r, sigma, n)
                        }
                        Kind::Scattered => {
                            if rng.below(100) < self.far_pct as u64 {
                                // hub-structured long-range coupling: rows
                                // of one block share FAR_HUBS possible
                                // targets (graph locality — without this,
                                // the pattern degenerates to all-to-all at
                                // scale, which cage14 is not)
                                let hub = rng.below(FAR_HUBS);
                                let center = cluster_center(self, row, hub, n);
                                band_col(&mut rng, center, 200.0, n)
                            } else {
                                band_col(&mut rng, r, self.band as f64, n)
                            }
                        }
                        Kind::Uniform => rng.usize_below(self.n),
                        Kind::Poisson2D => unreachable!(),
                    };
                    cols.push(c);
                }
            }
        }
        cols.sort_unstable();
        cols.dedup();
    }

    /// Row entries with diagonally-dominant values (off-diagonals in
    /// (-1, -0.5]; diagonal = 1 + Σ|off|), so Jacobi converges and the
    /// symmetrized Poisson case is SPD.
    pub fn row_entries(&self, row: usize, seed: u64) -> Vec<(usize, f64)> {
        if self.kind == Kind::Poisson2D {
            return self
                .row_cols(row, seed)
                .into_iter()
                .map(|c| (c, if c == row { 4.0 } else { -1.0 }))
                .collect();
        }
        let cols = self.row_cols(row, seed);
        let mut rng = self.row_rng(row, seed ^ 0xABCD);
        let mut entries: Vec<(usize, f64)> = Vec::with_capacity(cols.len());
        let mut offsum = 0.0;
        for c in cols {
            if c == row {
                entries.push((c, 0.0)); // placeholder, fixed below
            } else {
                let v = -(0.5 + 0.5 * rng.f64());
                offsum += v.abs();
                entries.push((c, v));
            }
        }
        for e in entries.iter_mut() {
            if e.0 == row {
                // strongly diagonally dominant (ρ_Jacobi ≤ 1/2)
                e.1 = 1.0 + 2.0 * offsum;
            }
        }
        entries
    }

    /// Materialize the full CSR matrix (small presets / examples only).
    pub fn to_csr(&self, seed: u64) -> CsrMatrix {
        let rows = (0..self.n).map(|r| self.row_entries(r, seed)).collect();
        CsrMatrix::from_rows(self.n, self.n, rows)
    }
}

fn band_col(rng: &mut Rng, center: i64, sigma: f64, n: i64) -> usize {
    let off = (rng.normal() * sigma).round() as i64;
    (center + off).clamp(0, n - 1) as usize
}

/// Rows per structural block sharing the same far-coupling hubs.
const HUB_BLOCK: usize = 2048;
/// Number of candidate far hubs per block (bounds per-rank neighbor
/// counts at scale — cage14's "high message count" is hundreds of
/// neighbors, not all-to-all).
const FAR_HUBS: u64 = 256;

/// Deterministic far-coupling target for (row block, hub index): a hash
/// independent of the per-row RNG stream, so all rows of a block agree.
fn cluster_center(preset: &MatrixPreset, row: usize, hub: u64, n: i64) -> i64 {
    let block = (row / HUB_BLOCK) as u64;
    let mut h = 0xcbf29ce484222325u64 ^ block.wrapping_mul(0x9E3779B97F4A7C15);
    h ^= hub.wrapping_mul(0xD1B54A32D192ED03);
    for b in preset.name.bytes().take(8) {
        h = h.wrapping_mul(0x100000001B3) ^ b as u64;
    }
    h = h ^ (h >> 29);
    h = h.wrapping_mul(0xBF58476D1CE4E5B9);
    (h % n as u64) as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_cols_deterministic_sorted_dedup() {
        let p = MatrixPreset::cage14_like().scaled(100);
        for row in [0usize, 1, 500, p.n - 1] {
            let a = p.row_cols(row, 42);
            let b = p.row_cols(row, 42);
            assert_eq!(a, b);
            assert!(a.contains(&row), "diagonal missing in row {row}");
            for w in a.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(a.iter().all(|&c| c < p.n));
        }
        // different seed → different structure
        assert_ne!(p.row_cols(500, 42), p.row_cols(500, 43));
    }

    #[test]
    fn poisson2d_stencil_exact() {
        let p = MatrixPreset::poisson2d(4, 3);
        assert_eq!(p.n, 12);
        // interior point (1,1) = row 5: all 5 neighbors
        assert_eq!(p.row_cols(5, 0), vec![1, 4, 5, 6, 9]);
        // corner (0,0): 3 entries
        assert_eq!(p.row_cols(0, 0), vec![0, 1, 4]);
        let a = p.to_csr(0);
        // symmetric
        for r in 0..a.nrows {
            for (idx, &c) in a.row_cols(r).iter().enumerate() {
                let v = a.row_vals(r)[idx];
                let back = a.row_cols(c).iter().position(|&cc| cc == r).unwrap();
                assert_eq!(a.row_vals(c)[back], v);
            }
        }
    }

    #[test]
    fn diag_dominance() {
        let p = MatrixPreset::fault_639_like().scaled(1000);
        let a = p.to_csr(7);
        for r in 0..a.nrows {
            let mut diag = 0.0;
            let mut off = 0.0;
            for (i, &c) in a.row_cols(r).iter().enumerate() {
                if c == r {
                    diag = a.row_vals(r)[i];
                } else {
                    off += a.row_vals(r)[i].abs();
                }
            }
            assert!(diag > off, "row {r} not diagonally dominant");
        }
    }

    #[test]
    fn paper_set_sizes() {
        let set = MatrixPreset::paper_set();
        assert_eq!(set.len(), 4);
        for p in &set {
            let nnz = p.approx_nnz();
            assert!(
                (6_000_000..40_000_000).contains(&nnz),
                "{}: nnz {nnz} far from 25M",
                p.name
            );
        }
    }

    #[test]
    fn scattered_reaches_far_columns() {
        let p = MatrixPreset::cage14_like().scaled(10);
        let mut far = 0;
        let mut total = 0;
        for row in (0..p.n).step_by(997) {
            for c in p.row_cols(row, 1) {
                total += 1;
                if (c as i64 - row as i64).unsigned_abs() as usize > p.n / 10 {
                    far += 1;
                }
            }
        }
        assert!(far * 100 / total >= 5, "far fraction only {far}/{total}");
    }

    #[test]
    fn banded_stays_near_diagonal() {
        let p = MatrixPreset::dielfilterv2clx_like().scaled(10);
        for row in (0..p.n).step_by(1003) {
            for c in p.row_cols(row, 1) {
                let d = (c as i64 - row as i64).unsigned_abs() as usize;
                assert!(d <= p.band * 8, "row {row} col {c} distance {d}");
            }
        }
    }

    #[test]
    fn scaled_preserves_shape() {
        let p = MatrixPreset::curlcurl_4_like();
        let s = p.scaled(100);
        assert_eq!(s.kind, p.kind);
        assert_eq!(s.deg, p.deg);
        assert!(s.n <= p.n / 99);
    }
}
