//! MatrixMarket reader: when the real SuiteSparse files are available
//! (e.g. `dielFilterV2clx.mtx`), the figure harness can run on them
//! instead of the synthetic analogs (`--mtx path`). Supports the
//! `coordinate` format with `real`/`integer`/`pattern` fields and
//! `general`/`symmetric` symmetry.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::csr::CsrMatrix;

/// Read a MatrixMarket `.mtx` file into CSR.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();

    let header = lines
        .next()
        .context("empty file")?
        .context("read header")?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 4 || !h[0].starts_with("%%MatrixMarket") || h[1] != "matrix" {
        bail!("not a MatrixMarket matrix header: {header}");
    }
    if h[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", h[2]);
    }
    let field = h[3];
    let symmetry = h.get(4).copied().unwrap_or("general");
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type {field}");
    }
    if !matches!(symmetry, "general" | "symmetric") {
        bail!("unsupported symmetry {symmetry}");
    }

    // Skip comments, read the size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.context("read line")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.context("missing size line")?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|x| x.parse().context("parse dims"))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("bad size line: {size_line}");
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);

    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); nrows];
    let mut seen = 0usize;
    for line in lines {
        let line = line.context("read entry")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("row")?.parse()?;
        let j: usize = it.next().context("col")?.parse()?;
        let v: f64 = match field {
            "pattern" => 1.0,
            _ => it.next().context("val")?.parse()?,
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            bail!("entry out of range: {t}");
        }
        rows[i - 1].push((j - 1, v));
        if symmetry == "symmetric" && i != j {
            rows[j - 1].push((i - 1, v));
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("expected {nnz} entries, found {seen}");
    }
    Ok(CsrMatrix::from_rows(nrows, ncols, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "sdde_mm_test_{}.mtx",
            std::process::id() as u64 + content.len() as u64
        ));
        let mut f = File::create(&p).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        p
    }

    #[test]
    fn reads_general_real() {
        let p = write_tmp(
            "%%MatrixMarket matrix coordinate real general\n\
             % comment\n\
             3 3 4\n\
             1 1 2.0\n\
             1 3 1.0\n\
             2 2 3.0\n\
             3 1 4.0\n",
        );
        let a = read_matrix_market(&p).unwrap();
        assert_eq!(a.nrows, 3);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.spmv(&[1.0, 1.0, 1.0]), vec![3.0, 3.0, 4.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn reads_symmetric_expands() {
        let p = write_tmp(
            "%%MatrixMarket matrix coordinate real symmetric\n\
             2 2 2\n\
             1 1 1.0\n\
             2 1 5.0\n",
        );
        let a = read_matrix_market(&p).unwrap();
        assert_eq!(a.nnz(), 3); // off-diag mirrored
        assert_eq!(a.spmv(&[1.0, 1.0]), vec![6.0, 5.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn reads_pattern() {
        let p = write_tmp(
            "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 2\n\
             1 2\n\
             2 1\n",
        );
        let a = read_matrix_market(&p).unwrap();
        assert_eq!(a.spmv(&[3.0, 4.0]), vec![4.0, 3.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = write_tmp("hello world\n");
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_wrong_nnz() {
        let p = write_tmp(
            "%%MatrixMarket matrix coordinate real general\n\
             2 2 2\n\
             1 1 1.0\n",
        );
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
