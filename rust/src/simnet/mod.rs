//! Deterministic virtual-time cluster simulator substrate.
//!
//! This is the testbed substitution for LLNL Quartz (see DESIGN.md):
//! * [`exec`] — a single-threaded async executor with a virtual clock.
//!   Every simulated rank is a plain `async fn`; blocking MPI semantics are
//!   expressed as futures; the executor advances virtual time by draining a
//!   deterministic event heap.
//! * [`topology`] — node → socket → core placement of ranks and the
//!   locality *tier* of any (src, dst) pair.
//! * [`cost`] — the LogGP-with-matching cost model and the two calibration
//!   presets standing in for OpenMPI 4.1.2 / Mvapich2 2.3.7 on Quartz.
//! * [`fault`] — seeded, deterministic perturbation plans (latency jitter,
//!   stragglers, forced rendezvous, duplicate delivery); off by default
//!   and bit-identical when off.

pub mod cost;
pub mod exec;
pub mod fault;
pub mod topology;

pub use cost::{CostModel, MpiFlavor};
pub use exec::{Sim, SimHandle, SimStats, Stall, Time};
pub use fault::{FaultPlan, FaultProfile, FaultState};
pub use topology::{RegionKind, Tier, Topology};
