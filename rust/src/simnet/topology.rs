//! Hierarchical cluster topology: node → socket → core, with ranks packed
//! sequentially across nodes (the layout the paper assumes: "if there are
//! PPN processes per region and ranks are laid out sequentially across the
//! regions, each process p has local rank p % PPN").

/// Locality tier of a (src, dst) pair, ordered from cheapest to most
/// expensive. The paper's regions aggregate over [`RegionKind`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// src == dst (self message; loopback copy).
    SelfMsg = 0,
    /// Same node, same socket.
    IntraSocket = 1,
    /// Same node, different socket.
    InterSocket = 2,
    /// Different node (crosses the NIC / interconnect).
    InterNode = 3,
}

/// Aggregation-region granularity for the locality-aware algorithms.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegionKind {
    Socket,
    Node,
}

impl RegionKind {
    pub fn parse(s: &str) -> Option<RegionKind> {
        match s {
            "socket" => Some(RegionKind::Socket),
            "node" => Some(RegionKind::Node),
            _ => None,
        }
    }
}

/// Cluster shape. `ppn` ranks per node are used (the paper uses 32 of the
/// 36 Quartz cores); ranks fill nodes sequentially, and within a node fill
/// socket 0 first, then socket 1 (block placement).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub sockets_per_node: usize,
    /// Ranks actually used per node (≤ sockets_per_node × cores_per_socket).
    pub ppn: usize,
}

impl Topology {
    /// Quartz-like: 2 sockets/node, `ppn` ranks per node.
    pub fn quartz(nodes: usize, ppn: usize) -> Topology {
        assert!(nodes >= 1 && ppn >= 1);
        Topology {
            nodes,
            sockets_per_node: 2,
            ppn,
        }
    }

    /// Paper default: 32 ranks per node.
    pub fn paper(nodes: usize) -> Topology {
        Topology::quartz(nodes, 32)
    }

    /// Single-node convenience (tests).
    pub fn single(ranks: usize) -> Topology {
        Topology {
            nodes: 1,
            sockets_per_node: 2,
            ppn: ranks,
        }
    }

    pub fn nranks(&self) -> usize {
        self.nodes * self.ppn
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ppn
    }

    /// Ranks per socket (block placement; last socket may be smaller if ppn
    /// does not divide evenly).
    fn per_socket(&self) -> usize {
        self.ppn.div_ceil(self.sockets_per_node)
    }

    pub fn socket_of(&self, rank: usize) -> usize {
        let local = rank % self.ppn;
        (self.node_of(rank) * self.sockets_per_node) + local / self.per_socket()
    }

    /// Locality tier of a message from `src` to `dst`.
    pub fn tier(&self, src: usize, dst: usize) -> Tier {
        if src == dst {
            Tier::SelfMsg
        } else if self.node_of(src) != self.node_of(dst) {
            Tier::InterNode
        } else if self.socket_of(src) != self.socket_of(dst) {
            Tier::InterSocket
        } else {
            Tier::IntraSocket
        }
    }

    /// Region id of `rank` at granularity `kind`.
    pub fn region_of(&self, rank: usize, kind: RegionKind) -> usize {
        match kind {
            RegionKind::Node => self.node_of(rank),
            RegionKind::Socket => self.socket_of(rank),
        }
    }

    /// Number of regions at granularity `kind`.
    pub fn num_regions(&self, kind: RegionKind) -> usize {
        match kind {
            RegionKind::Node => self.nodes,
            RegionKind::Socket => self.nodes * self.sockets_per_node,
        }
    }

    /// Ranks in region `r` at granularity `kind`, ascending.
    pub fn region_ranks(&self, r: usize, kind: RegionKind) -> Vec<usize> {
        (0..self.nranks())
            .filter(|&q| self.region_of(q, kind) == r)
            .collect()
    }

    /// Local rank of `rank` within its region (position among the region's
    /// ranks in ascending order).
    pub fn local_rank(&self, rank: usize, kind: RegionKind) -> usize {
        match kind {
            RegionKind::Node => rank % self.ppn,
            RegionKind::Socket => {
                let local = rank % self.ppn;
                local % self.per_socket()
            }
        }
    }

    /// Region size at granularity `kind` for the region containing `rank`.
    pub fn region_size(&self, rank: usize, kind: RegionKind) -> usize {
        match kind {
            RegionKind::Node => self.ppn,
            RegionKind::Socket => {
                let local = rank % self.ppn;
                let per = self.per_socket();
                let sock = local / per;
                let start = sock * per;
                (self.ppn - start).min(per)
            }
        }
    }

    /// The paper's corresponding-process rule: the rank in region `region`
    /// with local rank `local_rank(p)` — or, if that region is smaller than
    /// the sender's local rank, wrap around.
    pub fn corresponding_rank(&self, p: usize, region: usize, kind: RegionKind) -> usize {
        let ranks = self.region_ranks(region, kind);
        let lr = self.local_rank(p, kind);
        ranks[lr % ranks.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_layout_32ppn() {
        let t = Topology::paper(4);
        assert_eq!(t.nranks(), 128);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(31), 0);
        assert_eq!(t.node_of(32), 1);
        assert_eq!(t.local_rank(33, RegionKind::Node), 1);
        // block socket placement: 16 per socket
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(15), 0);
        assert_eq!(t.socket_of(16), 1);
        assert_eq!(t.socket_of(32), 2);
    }

    #[test]
    fn tiers() {
        let t = Topology::paper(2);
        assert_eq!(t.tier(5, 5), Tier::SelfMsg);
        assert_eq!(t.tier(0, 1), Tier::IntraSocket);
        assert_eq!(t.tier(0, 16), Tier::InterSocket);
        assert_eq!(t.tier(0, 32), Tier::InterNode);
        assert_eq!(t.tier(33, 1), Tier::InterNode);
    }

    #[test]
    fn regions_node() {
        let t = Topology::paper(3);
        assert_eq!(t.num_regions(RegionKind::Node), 3);
        assert_eq!(t.region_of(70, RegionKind::Node), 2);
        assert_eq!(t.region_ranks(1, RegionKind::Node), (32..64).collect::<Vec<_>>());
        assert_eq!(t.region_size(0, RegionKind::Node), 32);
    }

    #[test]
    fn regions_socket() {
        let t = Topology::paper(2);
        assert_eq!(t.num_regions(RegionKind::Socket), 4);
        assert_eq!(t.region_of(0, RegionKind::Socket), 0);
        assert_eq!(t.region_of(16, RegionKind::Socket), 1);
        assert_eq!(t.region_of(32, RegionKind::Socket), 2);
        assert_eq!(t.local_rank(17, RegionKind::Socket), 1);
        assert_eq!(t.region_size(17, RegionKind::Socket), 16);
    }

    #[test]
    fn corresponding_rank_rule() {
        let t = Topology::paper(2);
        // rank 3 (local rank 3 on node 0) corresponds to rank 32+3 on node 1.
        assert_eq!(t.corresponding_rank(3, 1, RegionKind::Node), 35);
        // and symmetric back.
        assert_eq!(t.corresponding_rank(35, 0, RegionKind::Node), 3);
    }

    #[test]
    fn odd_ppn_socket_split() {
        let t = Topology::quartz(1, 5); // 3 + 2 per socket
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(2), 0);
        assert_eq!(t.socket_of(3), 1);
        assert_eq!(t.region_size(0, RegionKind::Socket), 3);
        assert_eq!(t.region_size(4, RegionKind::Socket), 2);
        // every rank appears in exactly one socket region
        let all: Vec<usize> = (0..2)
            .flat_map(|s| t.region_ranks(s, RegionKind::Socket))
            .collect();
        assert_eq!(all.len(), 5);
    }

    #[test]
    fn corresponding_rank_wraps_for_uneven_regions() {
        let t = Topology::quartz(1, 5);
        // socket 1 has ranks {3,4}; a sender with local rank 2 wraps to 3.
        let p = 2; // socket 0, local rank 2
        let c = t.corresponding_rank(p, 1, RegionKind::Socket);
        assert!(t.region_ranks(1, RegionKind::Socket).contains(&c));
    }
}
