//! LogGP-with-matching network cost model, with calibration presets
//! standing in for the two system MPIs on Quartz (OpenMPI 4.1.2 and
//! Mvapich2 2.3.7 over Intel Omni-Path; see DESIGN.md §Substitutions).
//!
//! A point-to-point message from `src` to `dst` with `b` payload bytes is
//! charged:
//!
//! * sender side: the NIC is serialized — injection starts at
//!   `max(now, nic_free)` and occupies the NIC for
//!   `inj_gap[tier] + b · inj_per_byte[tier]`;
//! * wire: arrival at `inject_done + latency[tier] + b · per_byte[tier]`;
//! * receiver side: every probe/match operation scans the unexpected
//!   queue and is charged `match_base + match_per_entry · scanned`
//!   (the paper's "queue search cost");
//! * messages larger than `eager_limit` use a rendezvous protocol
//!   (RTS → match → data), adding one extra `latency[tier]` round;
//! * synchronous sends (`MPI_Issend`) complete only after a match
//!   acknowledgement travels back (`latency[tier]`).
//!
//! Constants are rough calibrations of Quartz-era measurements (sub-µs
//! intra-node latency, ~1.5–2 µs inter-node latency, ~12 GB/s injection
//! bandwidth, ~100 ns-scale match costs). The reproduction target is the
//! *shape* of the paper's figures, not absolute µs — see EXPERIMENTS.md.

use super::topology::Tier;
use crate::simnet::Time;

/// Which system MPI the preset emulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MpiFlavor {
    OpenMpi,
    Mvapich2,
}

impl MpiFlavor {
    pub fn parse(s: &str) -> Option<MpiFlavor> {
        match s.to_ascii_lowercase().as_str() {
            "openmpi" | "ompi" => Some(MpiFlavor::OpenMpi),
            "mvapich2" | "mvapich" | "mv2" => Some(MpiFlavor::Mvapich2),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            MpiFlavor::OpenMpi => "openmpi",
            MpiFlavor::Mvapich2 => "mvapich2",
        }
    }
}

/// Per-tier constants indexed by [`Tier`] as usize (SelfMsg..InterNode).
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// One-way latency per tier, ns.
    pub latency: [Time; 4],
    /// Per-byte wire time per tier, picoseconds per byte.
    pub per_byte_ps: [u64; 4],
    /// Sender NIC occupancy per message (gap), ns.
    pub inj_gap: [Time; 4],
    /// Sender NIC occupancy per byte, picoseconds per byte.
    pub inj_per_byte_ps: [u64; 4],
    /// Eager→rendezvous switchover, bytes.
    pub eager_limit: usize,
    /// Fixed cost of a probe/match operation, ns.
    pub match_base: Time,
    /// Additional cost per unexpected-queue entry scanned, ns.
    pub match_per_entry: Time,
    /// Per-call software overhead of posting a send/recv, ns.
    pub post_overhead: Time,
    /// Receiver-side per-message NIC/driver occupancy for *inter-node*
    /// messages, ns. Like `inj_gap`, this serializes on the shared
    /// per-node NIC (Quartz has one Omni-Path HFI per node — all 32 ranks
    /// contend for it; this is the dominant scaling bottleneck the
    /// locality-aware algorithms attack).
    pub rx_gap: Time,
    /// One-sided put: software overhead at origin, ns (no matching at all).
    pub rma_put_overhead: Time,
    /// Window fence: fixed synchronization overhead on top of the barrier, ns.
    pub rma_fence_overhead: Time,
    /// Per-element SUM reduction compute cost in allreduce, ns.
    pub reduce_per_elem: Time,
}

impl CostModel {
    /// Preset for the given MPI flavor (Quartz-like constants).
    pub fn preset(flavor: MpiFlavor) -> CostModel {
        match flavor {
            // Mvapich2: slightly lower p2p latency and cheaper RMA (the
            // paper's Fig. 5 shows RMA competitive under Mvapich2), but a
            // costlier allreduce implementation at scale.
            MpiFlavor::Mvapich2 => CostModel {
                latency: [80, 400, 700, 1_500],
                per_byte_ps: [15, 90, 180, 85],
                inj_gap: [20, 120, 200, 550],
                inj_per_byte_ps: [5, 30, 45, 80],
                eager_limit: 8 * 1024,
                match_base: 90,
                match_per_entry: 35,
                post_overhead: 60,
                rx_gap: 450,
                rma_put_overhead: 180,
                rma_fence_overhead: 900,
                reduce_per_elem: 1,
            },
            // OpenMPI: a bit higher latency & matching overheads, RMA over
            // UCX noticeably more expensive (the paper hit UCX errors /
            // worse RMA behaviour on OpenMPI).
            MpiFlavor::OpenMpi => CostModel {
                latency: [90, 450, 800, 1_800],
                per_byte_ps: [15, 95, 190, 90],
                inj_gap: [25, 140, 230, 650],
                inj_per_byte_ps: [5, 32, 50, 85],
                eager_limit: 4 * 1024,
                match_base: 110,
                match_per_entry: 45,
                post_overhead: 70,
                rx_gap: 520,
                rma_put_overhead: 420,
                rma_fence_overhead: 2_400,
                reduce_per_elem: 1,
            },
        }
    }

    #[inline]
    pub fn wire_time(&self, tier: Tier, bytes: usize) -> Time {
        let t = tier as usize;
        self.latency[t] + ((bytes as u128 * self.per_byte_ps[t] as u128) / 1_000) as Time
    }

    #[inline]
    pub fn inject_time(&self, tier: Tier, bytes: usize) -> Time {
        let t = tier as usize;
        self.inj_gap[t] + ((bytes as u128 * self.inj_per_byte_ps[t] as u128) / 1_000) as Time
    }

    #[inline]
    pub fn match_cost(&self, scanned: usize) -> Time {
        self.match_base + self.match_per_entry * scanned as Time
    }

    #[inline]
    pub fn is_rendezvous(&self, bytes: usize) -> bool {
        bytes > self.eager_limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_monotonicity() {
        for flavor in [MpiFlavor::OpenMpi, MpiFlavor::Mvapich2] {
            let c = CostModel::preset(flavor);
            // latency strictly increases with tier distance
            assert!(c.latency[0] < c.latency[1]);
            assert!(c.latency[1] < c.latency[2]);
            assert!(c.latency[2] < c.latency[3]);
            // a 1 KiB inter-node message is costlier than intra-socket
            assert!(
                c.wire_time(Tier::InterNode, 1024) > c.wire_time(Tier::IntraSocket, 1024)
            );
        }
    }

    #[test]
    fn wire_time_scales_with_bytes() {
        let c = CostModel::preset(MpiFlavor::Mvapich2);
        let small = c.wire_time(Tier::InterNode, 4);
        let big = c.wire_time(Tier::InterNode, 1_000_000);
        assert!(big > small);
        // ~85 ps/B → 1 MB ≈ 85 µs of serialization on the wire
        assert!(big - c.latency[3] > 80_000);
    }

    #[test]
    fn match_cost_linear_in_queue_len() {
        let c = CostModel::preset(MpiFlavor::OpenMpi);
        assert_eq!(
            c.match_cost(10) - c.match_cost(0),
            10 * c.match_per_entry
        );
    }

    #[test]
    fn eager_vs_rendezvous() {
        let c = CostModel::preset(MpiFlavor::Mvapich2);
        assert!(!c.is_rendezvous(4));
        assert!(!c.is_rendezvous(c.eager_limit));
        assert!(c.is_rendezvous(c.eager_limit + 1));
    }

    #[test]
    fn openmpi_rma_pricier_than_mvapich2() {
        let o = CostModel::preset(MpiFlavor::OpenMpi);
        let m = CostModel::preset(MpiFlavor::Mvapich2);
        assert!(o.rma_fence_overhead > m.rma_fence_overhead);
        assert!(o.rma_put_overhead > m.rma_put_overhead);
    }
}
