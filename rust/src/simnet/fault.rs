//! Seeded fault injection: deterministic network/CPU perturbation plans.
//!
//! A [`FaultPlan`] is a pure value — a seed plus a [`FaultProfile`] of
//! perturbation knobs. A world that is handed an *active* plan builds one
//! [`FaultState`] with an independent RNG stream per rank
//! (`derive_seed(plan.seed, rank)`), so every draw is a deterministic
//! function of (plan, rank, program order) and `--jobs N` sweeps stay
//! byte-identical to serial runs when each cell derives its own plan via
//! [`FaultPlan::for_cell`].
//!
//! Injection points (wired in `mpi::world`):
//!
//! * **latency jitter** — extra wire delay added *before* the per-(src,dst)
//!   FIFO clamp, so MPI non-overtaking is preserved by construction and
//!   only inter-pair interleavings are reordered (covers p2p and RMA puts);
//! * **straggler episodes** — per-rank periodic CPU-slowdown windows, a
//!   deterministic function of `(rank, now)` (no draws on the hot path);
//! * **forced rendezvous** — eager-eligible sends demoted to the
//!   rendezvous protocol (never self-messages);
//! * **duplicate delivery** — bounded retransmit-style second delivery of
//!   eager data; the matching layer must dedup it before matching.
//!
//! An inactive plan ([`FaultPlan::off`], or any all-zero profile) is
//! never materialized into a `FaultState`: zero RNG draws, zero extra
//! arithmetic, bit-identical virtual times (DESIGN.md invariant 8).

use std::cell::{Cell, RefCell};

use crate::simnet::Time;
use crate::util::rng::{derive_seed, Rng};

/// Fault-type codes stamped into the `tag` field of `EventKind::Fault`
/// trace events, so `sdde trace` can attribute makespan inflation.
pub const FAULT_JITTER: u32 = 0;
pub const FAULT_STRAGGLER: u32 = 1;
pub const FAULT_RENDEZVOUS: u32 = 2;
pub const FAULT_DUPLICATE: u32 = 3;

/// Human name for a fault-type code (trace rendering).
pub fn fault_name(code: u32) -> &'static str {
    match code {
        FAULT_JITTER => "jitter",
        FAULT_STRAGGLER => "straggler",
        FAULT_RENDEZVOUS => "forced-rendezvous",
        FAULT_DUPLICATE => "duplicate",
        _ => "fault",
    }
}

/// Perturbation knobs. All probabilities are per-opportunity; all times
/// are virtual ns. A profile with every knob zero is inactive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultProfile {
    /// Probability a message gets extra wire delay.
    pub jitter_prob: f64,
    /// Max extra delay, ns (uniform in `[1, max]` when hit).
    pub jitter_max_ns: Time,
    /// Probability a rank is a straggler at all (drawn once per world).
    pub straggler_prob: f64,
    /// CPU-cost multiplier inside a straggler episode.
    pub straggler_factor: u64,
    /// Episode period, ns (one slowdown window per period).
    pub straggler_period_ns: Time,
    /// Slowdown window length within each period, ns.
    pub straggler_duty_ns: Time,
    /// Probability an eager-eligible send is forced to rendezvous.
    pub force_rendezvous_prob: f64,
    /// Probability an eager delivery is duplicated (retransmit-style).
    pub duplicate_prob: f64,
    /// Max extra delay of the duplicate copy, ns.
    pub duplicate_delay_ns: Time,
    /// Per-rank budget of injected duplicates (bounded chaos).
    pub duplicate_budget: u32,
}

impl FaultProfile {
    /// All knobs zero: injects nothing.
    pub fn off() -> FaultProfile {
        FaultProfile {
            jitter_prob: 0.0,
            jitter_max_ns: 0,
            straggler_prob: 0.0,
            straggler_factor: 1,
            straggler_period_ns: 1,
            straggler_duty_ns: 0,
            force_rendezvous_prob: 0.0,
            duplicate_prob: 0.0,
            duplicate_delay_ns: 0,
            duplicate_budget: 0,
        }
    }

    /// Mild perturbation of every kind — the default for `--faults SEED`.
    pub fn light() -> FaultProfile {
        FaultProfile {
            jitter_prob: 0.25,
            jitter_max_ns: 2_500,
            straggler_prob: 0.0,
            force_rendezvous_prob: 0.05,
            duplicate_prob: 0.02,
            duplicate_delay_ns: 3_000,
            duplicate_budget: 8,
            ..FaultProfile::off()
        }
    }

    /// Aggressive everything: jitter, stragglers, demotion, duplicates.
    pub fn heavy() -> FaultProfile {
        FaultProfile {
            jitter_prob: 0.6,
            jitter_max_ns: 15_000,
            straggler_prob: 0.25,
            straggler_factor: 4,
            straggler_period_ns: 200_000,
            straggler_duty_ns: 60_000,
            force_rendezvous_prob: 0.2,
            duplicate_prob: 0.1,
            duplicate_delay_ns: 10_000,
            duplicate_budget: 64,
        }
    }

    /// Only latency jitter / reordering.
    pub fn jitter() -> FaultProfile {
        FaultProfile {
            jitter_prob: 0.8,
            jitter_max_ns: 20_000,
            ..FaultProfile::off()
        }
    }

    /// Only per-rank CPU slowdown episodes.
    pub fn straggler() -> FaultProfile {
        FaultProfile {
            straggler_prob: 0.5,
            straggler_factor: 8,
            straggler_period_ns: 100_000,
            straggler_duty_ns: 50_000,
            ..FaultProfile::off()
        }
    }

    /// Every eligible send demoted to rendezvous.
    pub fn rendezvous() -> FaultProfile {
        FaultProfile {
            force_rendezvous_prob: 1.0,
            ..FaultProfile::off()
        }
    }

    /// Only duplicate deliveries.
    pub fn duplicate() -> FaultProfile {
        FaultProfile {
            duplicate_prob: 0.25,
            duplicate_delay_ns: 8_000,
            duplicate_budget: 256,
            ..FaultProfile::off()
        }
    }

    pub fn parse(name: &str) -> Result<FaultProfile, String> {
        match name {
            "off" => Ok(FaultProfile::off()),
            "light" => Ok(FaultProfile::light()),
            "heavy" => Ok(FaultProfile::heavy()),
            "jitter" => Ok(FaultProfile::jitter()),
            "straggler" => Ok(FaultProfile::straggler()),
            "rendezvous" | "rdv" => Ok(FaultProfile::rendezvous()),
            "duplicate" | "dup" => Ok(FaultProfile::duplicate()),
            _ => Err(format!(
                "unknown fault profile '{name}' \
                 (off|light|heavy|jitter|straggler|rendezvous|duplicate)"
            )),
        }
    }

    /// Does this profile inject anything at all?
    pub fn is_active(&self) -> bool {
        self.jitter_prob > 0.0
            || self.straggler_prob > 0.0
            || self.force_rendezvous_prob > 0.0
            || self.duplicate_prob > 0.0
    }
}

/// A seeded perturbation plan for one world. Plain data (`Copy`) so sweep
/// cells can carry it across threads; the mutable per-rank streams live
/// in [`FaultState`], built per world.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub profile: FaultProfile,
}

impl FaultPlan {
    /// The do-nothing plan: worlds built with it are bit-identical to
    /// worlds built with no plan at all (enforced by regression test).
    pub fn off() -> FaultPlan {
        FaultPlan {
            seed: 0,
            profile: FaultProfile::off(),
        }
    }

    /// Default (light) profile under the given seed.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            profile: FaultProfile::light(),
        }
    }

    pub fn with_profile(seed: u64, profile: FaultProfile) -> FaultPlan {
        FaultPlan { seed, profile }
    }

    /// Parse the CLI form `SEED[:PROFILE]`, e.g. `42` or `42:heavy`.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let (seed_s, prof_s) = match s.split_once(':') {
            Some((a, b)) => (a, Some(b)),
            None => (s, None),
        };
        let seed: u64 = seed_s
            .parse()
            .map_err(|_| format!("bad fault seed '{seed_s}' (want SEED[:PROFILE])"))?;
        let profile = match prof_s {
            Some(p) => FaultProfile::parse(p)?,
            None => FaultProfile::light(),
        };
        Ok(FaultPlan { seed, profile })
    }

    pub fn is_active(&self) -> bool {
        self.profile.is_active()
    }

    /// Independent child plan for sweep cell `cell` — same profile, seed
    /// derived with [`derive_seed`] so cells don't share streams and the
    /// assignment of cells to worker threads can't matter (invariant 7).
    pub fn for_cell(&self, cell: u64) -> FaultPlan {
        FaultPlan {
            seed: derive_seed(self.seed, cell),
            profile: self.profile,
        }
    }
}

/// Per-rank straggler schedule: slow inside a periodic window. Purely a
/// function of `now`, so CPU charges never consume RNG draws.
#[derive(Clone, Copy, Debug)]
struct Straggler {
    factor: u64,
    period: Time,
    duty: Time,
    phase: Time,
}

struct FaultRank {
    /// Stream for this rank's send-side draws (jitter, demotion, dup).
    rng: RefCell<Rng>,
    straggler: Option<Straggler>,
    dup_left: Cell<u32>,
}

/// Mutable per-world fault state. Only built for active plans; `None`
/// elsewhere keeps the fault-off fast path free of any fault arithmetic.
pub struct FaultState {
    profile: FaultProfile,
    ranks: Vec<FaultRank>,
    injected: Cell<u64>,
}

impl FaultState {
    pub fn new(plan: FaultPlan, nranks: usize) -> FaultState {
        let p = plan.profile;
        let ranks = (0..nranks)
            .map(|r| {
                // Separate derivation chain for the one-shot straggler
                // election so it never perturbs the per-message stream.
                let mut elect = Rng::substream(derive_seed(plan.seed, 0xFA17), r as u64);
                let straggler = if p.straggler_prob > 0.0
                    && p.straggler_factor > 1
                    && p.straggler_duty_ns > 0
                    && elect.chance(p.straggler_prob)
                {
                    Some(Straggler {
                        factor: p.straggler_factor,
                        period: p.straggler_period_ns.max(1),
                        duty: p.straggler_duty_ns,
                        phase: elect.below(p.straggler_period_ns.max(1)),
                    })
                } else {
                    None
                };
                FaultRank {
                    rng: RefCell::new(Rng::substream(plan.seed, r as u64)),
                    straggler,
                    dup_left: Cell::new(p.duplicate_budget),
                }
            })
            .collect();
        FaultState {
            profile: p,
            ranks,
            injected: Cell::new(0),
        }
    }

    /// Total faults injected so far (all kinds).
    pub fn injected(&self) -> u64 {
        self.injected.get()
    }

    fn count(&self) {
        self.injected.set(self.injected.get() + 1);
    }

    /// Extra wire delay for a message leaving `src` (0 = no fault).
    pub fn jitter(&self, src: usize) -> Time {
        if self.profile.jitter_prob <= 0.0 || self.profile.jitter_max_ns == 0 {
            return 0;
        }
        let mut rng = self.ranks[src].rng.borrow_mut();
        if rng.chance(self.profile.jitter_prob) {
            self.count();
            1 + rng.below(self.profile.jitter_max_ns)
        } else {
            0
        }
    }

    /// Should this eager-eligible send be demoted to rendezvous?
    pub fn force_rendezvous(&self, src: usize) -> bool {
        if self.profile.force_rendezvous_prob <= 0.0 {
            return false;
        }
        let hit = self.ranks[src]
            .rng
            .borrow_mut()
            .chance(self.profile.force_rendezvous_prob);
        if hit {
            self.count();
        }
        hit
    }

    /// Should this eager delivery be duplicated? Returns the extra delay
    /// of the retransmitted copy. Bounded by the per-rank budget.
    pub fn duplicate(&self, src: usize) -> Option<Time> {
        if self.profile.duplicate_prob <= 0.0 {
            return None;
        }
        let fr = &self.ranks[src];
        if fr.dup_left.get() == 0 {
            return None;
        }
        let mut rng = fr.rng.borrow_mut();
        if rng.chance(self.profile.duplicate_prob) {
            fr.dup_left.set(fr.dup_left.get() - 1);
            self.count();
            Some(1 + rng.below(self.profile.duplicate_delay_ns.max(1)))
        } else {
            None
        }
    }

    /// CPU cost after any straggler slowdown at virtual time `now`.
    /// Deterministic in `(rank, now)`; consumes no RNG draws.
    pub fn slowed(&self, rank: usize, now: Time, cost: Time) -> Time {
        match &self.ranks[rank].straggler {
            Some(s) if (now + s.phase) % s.period < s.duty => {
                if cost > 0 {
                    self.count();
                }
                cost.saturating_mul(s.factor)
            }
            _ => cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_is_inactive() {
        assert!(!FaultPlan::off().is_active());
        assert!(!FaultProfile::off().is_active());
        assert!(FaultPlan::seeded(1).is_active());
        for p in [
            FaultProfile::light(),
            FaultProfile::heavy(),
            FaultProfile::jitter(),
            FaultProfile::straggler(),
            FaultProfile::rendezvous(),
            FaultProfile::duplicate(),
        ] {
            assert!(p.is_active());
        }
    }

    #[test]
    fn parse_forms() {
        assert_eq!(FaultPlan::parse("42").unwrap(), FaultPlan::seeded(42));
        assert_eq!(
            FaultPlan::parse("7:heavy").unwrap(),
            FaultPlan::with_profile(7, FaultProfile::heavy())
        );
        assert_eq!(
            FaultPlan::parse("0:off").unwrap().profile,
            FaultProfile::off()
        );
        assert!(FaultPlan::parse("nope").is_err());
        assert!(FaultPlan::parse("1:gremlins").is_err());
    }

    #[test]
    fn draws_are_deterministic_per_rank() {
        let mk = || FaultState::new(FaultPlan::seeded(99), 4);
        let a = mk();
        let b = mk();
        for r in 0..4 {
            for _ in 0..50 {
                assert_eq!(a.jitter(r), b.jitter(r));
                assert_eq!(a.force_rendezvous(r), b.force_rendezvous(r));
                assert_eq!(a.duplicate(r), b.duplicate(r));
            }
        }
        assert_eq!(a.injected(), b.injected());
    }

    #[test]
    fn ranks_have_independent_streams() {
        let s = FaultState::new(
            FaultPlan::with_profile(3, FaultProfile::jitter()),
            2,
        );
        let a: Vec<Time> = (0..64).map(|_| s.jitter(0)).collect();
        let b: Vec<Time> = (0..64).map(|_| s.jitter(1)).collect();
        assert_ne!(a, b);
        // Interleaving order across ranks must not matter: each rank has
        // its own stream, so rank 0's draws are a function of rank 0 only.
        let s2 = FaultState::new(
            FaultPlan::with_profile(3, FaultProfile::jitter()),
            2,
        );
        let mut a2 = Vec::new();
        let mut b2 = Vec::new();
        for _ in 0..64 {
            b2.push(s2.jitter(1));
            a2.push(s2.jitter(0));
        }
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn duplicate_budget_is_bounded() {
        let mut prof = FaultProfile::duplicate();
        prof.duplicate_prob = 1.0;
        prof.duplicate_budget = 5;
        let s = FaultState::new(FaultPlan::with_profile(1, prof), 1);
        let hits = (0..100).filter(|_| s.duplicate(0).is_some()).count();
        assert_eq!(hits, 5);
    }

    #[test]
    fn straggler_slowdown_is_windowed_and_drawless() {
        let plan = FaultPlan::with_profile(11, FaultProfile::straggler());
        let p = FaultProfile::straggler();
        let s = FaultState::new(plan, 8);
        // At least one rank elected with prob 0.5 over 8 ranks (seeded:
        // deterministic — if this ever fails the seed just needs bumping).
        let slow_rank = (0..8).find(|&r| {
            (0..p.straggler_period_ns)
                .step_by(1000)
                .any(|t| s.slowed(r, t, 100) > 100)
        });
        let r = slow_rank.expect("no straggler elected under seed 11");
        // Within one period the factor applies in the duty window only,
        // and repeated queries at the same `now` agree (no draws).
        let mut saw_fast = false;
        let mut saw_slow = false;
        for t in (0..p.straggler_period_ns * 2).step_by(500) {
            let c1 = s.slowed(r, t, 100);
            let c2 = s.slowed(r, t, 100);
            assert_eq!(c1, c2);
            match c1 {
                100 => saw_fast = true,
                c if c == 100 * p.straggler_factor => saw_slow = true,
                c => panic!("unexpected slowed cost {c}"),
            }
        }
        assert!(saw_fast && saw_slow);
    }

    #[test]
    fn for_cell_derives_distinct_plans() {
        let p = FaultPlan::seeded(42);
        assert_ne!(p.for_cell(0).seed, p.for_cell(1).seed);
        assert_eq!(p.for_cell(3), p.for_cell(3));
        assert_eq!(p.for_cell(0).profile, p.profile);
    }

    #[test]
    fn fault_names_cover_codes() {
        assert_eq!(fault_name(FAULT_JITTER), "jitter");
        assert_eq!(fault_name(FAULT_STRAGGLER), "straggler");
        assert_eq!(fault_name(FAULT_RENDEZVOUS), "forced-rendezvous");
        assert_eq!(fault_name(FAULT_DUPLICATE), "duplicate");
    }
}
