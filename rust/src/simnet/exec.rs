//! Deterministic single-threaded async executor with a virtual clock.
//!
//! Simulated ranks are ordinary `async fn`s spawned as tasks. Time only
//! advances when every runnable task has yielded: the executor then pops the
//! earliest timer event, sets the virtual clock, and runs the event's
//! callback (which typically mutates shared state — e.g. delivers a message
//! into a rank's unexpected queue — and wakes a task).
//!
//! Determinism: the ready queue is FIFO, the timer heap breaks time ties by
//! insertion sequence number, and everything runs on one OS thread, so a
//! given program + seed always produces the same interleaving and the same
//! virtual end time.

use std::cell::{Cell, RefCell};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::{Rc, Weak};
use std::task::{Context, Poll, RawWaker, RawWakerVTable, Waker};

/// Virtual time in nanoseconds since simulation start.
pub type Time = u64;

/// Executor statistics reported by [`Sim::stats`] — the §Perf metric of
/// the discrete-event engine itself (host-side work, not virtual time).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Timer events popped and run (message deliveries, sleeps, wakes).
    pub events_run: u64,
    /// Futures polled (ready-queue drains; counts re-polls after wakes).
    pub polls: u64,
    /// Host wall-clock time spent inside [`Sim::run`], ns. Cumulative over
    /// repeated `run` calls; 0 until the first call returns.
    pub host_ns: u64,
}

impl SimStats {
    /// Host-side engine throughput: timer events per wall-clock second.
    pub fn events_per_sec(&self) -> f64 {
        if self.host_ns == 0 {
            0.0
        } else {
            self.events_run as f64 * 1e9 / self.host_ns as f64
        }
    }
}

/// Why a simulation stopped making progress (returned by [`Sim::try_run`]
/// instead of hanging or panicking; `mpi::World` turns it into a
/// `WaitGraph` diagnostic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stall {
    /// Timer heap empty with live tasks: nothing can ever run again.
    Deadlock { live_tasks: usize },
    /// The watchdog tripped: virtual time ran more than the configured
    /// quiet horizon past the last progress mark while tasks were still
    /// blocked — a livelock or lost-progress hang (e.g. a polling loop
    /// that burns virtual time on a request that never completes).
    Quiescent { live_tasks: usize, last_progress: Time },
}

impl Stall {
    pub fn live_tasks(&self) -> usize {
        match *self {
            Stall::Deadlock { live_tasks } => live_tasks,
            Stall::Quiescent { live_tasks, .. } => live_tasks,
        }
    }

    pub fn is_deadlock(&self) -> bool {
        matches!(self, Stall::Deadlock { .. })
    }
}

type BoxFut = Pin<Box<dyn Future<Output = ()> + 'static>>;
type EventCb = Box<dyn FnOnce() + 'static>;

/// Timer payload: waking a task directly (the overwhelmingly common case —
/// every `Sleep`) avoids a callback Box allocation per event.
enum TimerAction {
    Wake(Waker),
    Call(EventCb),
}

/// Owner handle: create tasks, then [`Sim::run`] to completion.
pub struct Sim {
    inner: Rc<SimInner>,
}

/// Cheap clonable handle used by futures and event callbacks.
#[derive(Clone)]
pub struct SimHandle {
    inner: Weak<SimInner>,
}

#[derive(Default)]
struct SimInner {
    now: Cell<Time>,
    seq: Cell<u64>,
    ready: RefCell<VecDeque<usize>>,
    queued: RefCell<Vec<bool>>,
    tasks: RefCell<Vec<Option<BoxFut>>>,
    /// Lazily-created cached waker per task (§Perf: one Rc per task, not
    /// one per poll).
    wakers: RefCell<Vec<Option<Waker>>>,
    live_tasks: Cell<usize>,
    /// Timer heap: Reverse((time, seq, action-slot)).
    timers: RefCell<BinaryHeap<Reverse<(Time, u64, usize)>>>,
    callbacks: RefCell<Vec<Option<TimerAction>>>,
    free_cb_slots: RefCell<Vec<usize>>,
    events_run: Cell<u64>,
    polls: Cell<u64>,
    host_ns: Cell<u64>,
    /// Virtual time of the last externally-reported progress (message
    /// delivery etc.; see [`SimHandle::note_progress`]). Watchdog state.
    progress_mark: Cell<Time>,
    /// Quiescence watchdog: if set, stall when the next event lies more
    /// than this many ns past `progress_mark` with tasks still live.
    quiet_horizon: Cell<Option<Time>>,
}

// ---------------------------------------------------------------------------
// Waker plumbing: a Waker whose data pointer is an Rc<WakeSlot>. Safe for a
// single-threaded executor (wakers never cross threads here).
// ---------------------------------------------------------------------------

struct WakeSlot {
    exec: Weak<SimInner>,
    task: usize,
}

impl WakeSlot {
    fn wake(&self) {
        if let Some(exec) = self.exec.upgrade() {
            exec.enqueue(self.task);
        }
    }
}

const VTABLE: RawWakerVTable = RawWakerVTable::new(wk_clone, wk_wake, wk_wake_by_ref, wk_drop);

unsafe fn wk_clone(p: *const ()) -> RawWaker {
    Rc::increment_strong_count(p as *const WakeSlot);
    RawWaker::new(p, &VTABLE)
}
unsafe fn wk_wake(p: *const ()) {
    let slot = Rc::from_raw(p as *const WakeSlot);
    slot.wake();
}
unsafe fn wk_wake_by_ref(p: *const ()) {
    let slot = &*(p as *const WakeSlot);
    slot.wake();
}
unsafe fn wk_drop(p: *const ()) {
    drop(Rc::from_raw(p as *const WakeSlot));
}

fn make_waker(exec: &Rc<SimInner>, task: usize) -> Waker {
    let slot = Rc::new(WakeSlot {
        exec: Rc::downgrade(exec),
        task,
    });
    let raw = RawWaker::new(Rc::into_raw(slot) as *const (), &VTABLE);
    // SAFETY: the vtable upholds RawWaker's contract; single-threaded use.
    unsafe { Waker::from_raw(raw) }
}

impl SimInner {
    fn enqueue(&self, task: usize) {
        let mut queued = self.queued.borrow_mut();
        if task < queued.len() && !queued[task] {
            queued[task] = true;
            self.ready.borrow_mut().push_back(task);
        }
    }

    fn schedule_action(&self, at: Time, action: TimerAction) {
        debug_assert!(at >= self.now.get(), "scheduling into the past");
        let slot = match self.free_cb_slots.borrow_mut().pop() {
            Some(s) => {
                self.callbacks.borrow_mut()[s] = Some(action);
                s
            }
            None => {
                let mut cbs = self.callbacks.borrow_mut();
                cbs.push(Some(action));
                cbs.len() - 1
            }
        };
        let seq = self.seq.get();
        self.seq.set(seq + 1);
        self.timers.borrow_mut().push(Reverse((at, seq, slot)));
    }

    fn schedule(&self, at: Time, cb: EventCb) {
        self.schedule_action(at, TimerAction::Call(cb));
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim {
            inner: Rc::new(SimInner::default()),
        }
    }

    pub fn handle(&self) -> SimHandle {
        SimHandle {
            inner: Rc::downgrade(&self.inner),
        }
    }

    /// Spawn a task; it becomes runnable immediately.
    pub fn spawn<F: Future<Output = ()> + 'static>(&self, fut: F) {
        let mut tasks = self.inner.tasks.borrow_mut();
        let id = tasks.len();
        tasks.push(Some(Box::pin(fut)));
        drop(tasks);
        self.inner.queued.borrow_mut().push(false);
        self.inner.wakers.borrow_mut().push(None);
        self.inner.live_tasks.set(self.inner.live_tasks.get() + 1);
        self.inner.enqueue(id);
    }

    /// Run until no task is runnable and no timer is pending.
    ///
    /// Returns the final virtual time. Panics if tasks remain alive but
    /// nothing can make progress (a deadlock in the simulated program).
    pub fn run(&self) -> Time {
        match self.try_run() {
            Ok(t) => t,
            Err(Stall::Deadlock { live_tasks }) => panic!(
                "simulation deadlock: {} task(s) blocked with no pending events at t={}",
                live_tasks,
                self.inner.now.get()
            ),
            Err(Stall::Quiescent {
                live_tasks,
                last_progress,
            }) => panic!(
                "simulation deadlock (quiescent): {} task(s) made no progress \
                 since t={} (quiet horizon exceeded at t={})",
                live_tasks,
                last_progress,
                self.inner.now.get()
            ),
        }
    }

    /// Like [`Sim::run`], but a stalled simulation returns [`Stall`]
    /// instead of panicking (or, for livelocks under a quiet horizon,
    /// spinning forever). On `Err` the simulation state is left intact so
    /// callers can build diagnostics from it.
    pub fn try_run(&self) -> Result<Time, Stall> {
        let host_t0 = std::time::Instant::now();
        let res = self.run_loop();
        self.inner
            .host_ns
            .set(self.inner.host_ns.get() + host_t0.elapsed().as_nanos() as u64);
        res
    }

    fn run_loop(&self) -> Result<Time, Stall> {
        loop {
            // Drain all runnable tasks at the current instant.
            loop {
                let id = self.inner.ready.borrow_mut().pop_front();
                let Some(id) = id else { break };
                self.inner.queued.borrow_mut()[id] = false;
                let fut = self.inner.tasks.borrow_mut()[id].take();
                let Some(mut fut) = fut else { continue };
                // Cached per-task waker (created once, cloned cheaply).
                let waker = {
                    let mut wakers = self.inner.wakers.borrow_mut();
                    if wakers[id].is_none() {
                        wakers[id] = Some(make_waker(&self.inner, id));
                    }
                    wakers[id].as_ref().unwrap().clone()
                };
                let mut cx = Context::from_waker(&waker);
                self.inner.polls.set(self.inner.polls.get() + 1);
                match fut.as_mut().poll(&mut cx) {
                    Poll::Pending => {
                        self.inner.tasks.borrow_mut()[id] = Some(fut);
                    }
                    Poll::Ready(()) => {
                        self.inner.live_tasks.set(self.inner.live_tasks.get() - 1);
                        self.inner.wakers.borrow_mut()[id] = None;
                    }
                }
            }
            // Advance virtual time to the next event. Before committing,
            // let the quiescence watchdog veto a march past the horizon:
            // live tasks + a long progress-free stretch of virtual time is
            // a livelock (e.g. a poll loop on a request nobody completes).
            let next = self.inner.timers.borrow_mut().pop();
            match next {
                Some(Reverse((t, _, slot))) => {
                    if let Some(h) = self.inner.quiet_horizon.get() {
                        if self.inner.live_tasks.get() > 0
                            && t > self.inner.progress_mark.get().saturating_add(h)
                        {
                            return Err(Stall::Quiescent {
                                live_tasks: self.inner.live_tasks.get(),
                                last_progress: self.inner.progress_mark.get(),
                            });
                        }
                    }
                    debug_assert!(t >= self.inner.now.get());
                    self.inner.now.set(t);
                    let action = self.inner.callbacks.borrow_mut()[slot].take();
                    self.inner.free_cb_slots.borrow_mut().push(slot);
                    self.inner.events_run.set(self.inner.events_run.get() + 1);
                    match action {
                        Some(TimerAction::Wake(w)) => w.wake(),
                        Some(TimerAction::Call(cb)) => cb(),
                        None => {}
                    }
                }
                None => {
                    if self.inner.live_tasks.get() > 0 {
                        return Err(Stall::Deadlock {
                            live_tasks: self.inner.live_tasks.get(),
                        });
                    }
                    return Ok(self.inner.now.get());
                }
            }
        }
    }

    pub fn now(&self) -> Time {
        self.inner.now.get()
    }

    /// Arm (or disarm with `None`) the quiescence watchdog: the run stalls
    /// with [`Stall::Quiescent`] when virtual time would advance more than
    /// `horizon` ns past the last [`SimHandle::note_progress`] call while
    /// tasks are still live. Off by default. The horizon must exceed the
    /// longest legitimate progress-free stretch of the program (sleeps,
    /// fences); progress is whatever the embedding layer says it is —
    /// `mpi::World` marks every message delivery.
    pub fn set_quiet_horizon(&self, horizon: Option<Time>) {
        self.inner.quiet_horizon.set(horizon);
    }

    /// Executor statistics — used by the §Perf harness.
    pub fn stats(&self) -> SimStats {
        SimStats {
            events_run: self.inner.events_run.get(),
            polls: self.inner.polls.get(),
            host_ns: self.inner.host_ns.get(),
        }
    }
}

impl SimHandle {
    fn upgrade(&self) -> Rc<SimInner> {
        self.inner.upgrade().expect("simulation already dropped")
    }

    /// Spawn a task from inside the simulation (e.g. a background
    /// non-blocking-barrier progress engine).
    pub fn spawn<F: Future<Output = ()> + 'static>(&self, fut: F) {
        let inner = self.upgrade();
        let mut tasks = inner.tasks.borrow_mut();
        let id = tasks.len();
        tasks.push(Some(Box::pin(fut)));
        drop(tasks);
        inner.queued.borrow_mut().push(false);
        inner.wakers.borrow_mut().push(None);
        inner.live_tasks.set(inner.live_tasks.get() + 1);
        inner.enqueue(id);
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.upgrade().now.get()
    }

    /// Mark "the simulation is making progress" for the quiescence
    /// watchdog (see [`Sim::set_quiet_horizon`]). One Cell store; safe to
    /// call on hot paths whether or not the watchdog is armed.
    pub fn note_progress(&self) {
        let inner = self.upgrade();
        inner.progress_mark.set(inner.now.get());
    }

    /// Schedule `cb` to run at absolute virtual time `at`.
    pub fn schedule(&self, at: Time, cb: impl FnOnce() + 'static) {
        self.upgrade().schedule(at, Box::new(cb));
    }

    /// Schedule `cb` to run `delay` ns from now.
    pub fn schedule_in(&self, delay: Time, cb: impl FnOnce() + 'static) {
        let inner = self.upgrade();
        inner.schedule(inner.now.get() + delay, Box::new(cb));
    }

    /// Sleep until absolute virtual time `at`.
    pub fn sleep_until(&self, at: Time) -> Sleep {
        Sleep {
            sim: self.clone(),
            at,
            scheduled: false,
        }
    }

    /// Sleep for `d` ns of virtual time.
    pub fn sleep(&self, d: Time) -> Sleep {
        let at = self.now() + d;
        self.sleep_until(at)
    }
}

/// Future returned by [`SimHandle::sleep`].
pub struct Sleep {
    sim: SimHandle,
    at: Time,
    scheduled: bool,
}

impl Future for Sleep {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let inner = self.sim.upgrade();
        if inner.now.get() >= self.at {
            return Poll::Ready(());
        }
        if !self.scheduled {
            self.scheduled = true;
            inner.schedule_action(self.at, TimerAction::Wake(cx.waker().clone()));
        }
        Poll::Pending
    }
}

/// Cooperative yield: requeue the current task behind the ready queue
/// without advancing time. Used to break livelocks in polling loops.
pub fn yield_now() -> YieldNow {
    YieldNow { yielded: false }
}

pub struct YieldNow {
    yielded: bool,
}

impl Future for YieldNow {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.yielded {
            Poll::Ready(())
        } else {
            self.yielded = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn empty_sim_runs() {
        let sim = Sim::new();
        assert_eq!(sim.run(), 0);
    }

    #[test]
    fn sleep_advances_virtual_time() {
        let sim = Sim::new();
        let h = sim.handle();
        sim.spawn(async move {
            h.sleep(1_000).await;
            h.sleep(500).await;
        });
        assert_eq!(sim.run(), 1_500);
    }

    #[test]
    fn tasks_interleave_deterministically() {
        let sim = Sim::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for id in 0..3u32 {
            let h = sim.handle();
            let order = order.clone();
            sim.spawn(async move {
                h.sleep((3 - id as u64) * 100).await;
                order.borrow_mut().push(id);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec![2, 1, 0]);
    }

    #[test]
    fn same_deadline_fifo_by_schedule_order() {
        let sim = Sim::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for id in 0..4u32 {
            let h = sim.handle();
            let order = order.clone();
            sim.spawn(async move {
                h.sleep(100).await;
                order.borrow_mut().push(id);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn event_callback_wakes_task() {
        let sim = Sim::new();
        let h = sim.handle();
        let flag = Rc::new(Cell::new(false));
        let flag2 = flag.clone();
        // A "message delivery" at t=42 sets the flag; the task busy-waits
        // via a manually-registered waker through sleep polling.
        sim.spawn(async move {
            h.schedule_in(42, move || flag2.set(true));
            h.sleep(100).await;
            assert!(flag.get());
        });
        assert_eq!(sim.run(), 100);
    }

    #[test]
    fn yield_now_keeps_time() {
        let sim = Sim::new();
        sim.spawn(async move {
            for _ in 0..10 {
                yield_now().await;
            }
        });
        assert_eq!(sim.run(), 0);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn deadlock_detected() {
        let sim = Sim::new();
        sim.spawn(async move {
            // A future that is never woken.
            std::future::pending::<()>().await;
        });
        sim.run();
    }

    #[test]
    fn try_run_reports_deadlock_without_panicking() {
        let sim = Sim::new();
        sim.spawn(async move {
            std::future::pending::<()>().await;
        });
        assert_eq!(sim.try_run(), Err(Stall::Deadlock { live_tasks: 1 }));
    }

    #[test]
    fn try_run_completes_like_run() {
        let sim = Sim::new();
        let h = sim.handle();
        sim.spawn(async move {
            h.sleep(250).await;
        });
        assert_eq!(sim.try_run(), Ok(250));
    }

    #[test]
    fn quiet_horizon_stalls_a_livelock() {
        // A task that burns virtual time forever waiting on a wake that
        // never comes: without the watchdog this loops on the host too.
        let sim = Sim::new();
        sim.set_quiet_horizon(Some(10_000));
        let h = sim.handle();
        sim.spawn(async move {
            loop {
                h.sleep(1_000).await;
            }
        });
        match sim.try_run() {
            Err(Stall::Quiescent {
                live_tasks,
                last_progress,
            }) => {
                assert_eq!(live_tasks, 1);
                assert_eq!(last_progress, 0);
            }
            other => panic!("expected quiescent stall, got {other:?}"),
        }
        assert!(sim.now() <= 10_000);
    }

    #[test]
    fn note_progress_feeds_the_watchdog() {
        let sim = Sim::new();
        sim.set_quiet_horizon(Some(5_000));
        let h = sim.handle();
        sim.spawn(async move {
            for _ in 0..10 {
                h.sleep(4_000).await;
                h.note_progress(); // deliveries keep the watchdog fed
            }
        });
        assert_eq!(sim.try_run(), Ok(40_000));
    }

    #[test]
    fn horizon_none_never_stalls_terminating_programs() {
        let sim = Sim::new();
        sim.set_quiet_horizon(None);
        let h = sim.handle();
        sim.spawn(async move {
            h.sleep(1_000_000).await;
        });
        assert_eq!(sim.try_run(), Ok(1_000_000));
    }

    #[test]
    fn spawn_many_scales() {
        let sim = Sim::new();
        for i in 0..2048u64 {
            let h = sim.handle();
            sim.spawn(async move {
                h.sleep(i % 17).await;
                h.sleep(3).await;
            });
        }
        let end = sim.run();
        assert_eq!(end, 16 + 3);
    }
}
