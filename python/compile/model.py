"""L2 — the JAX compute graph the rust coordinator executes per solver
iteration: the local Block-ELL SpMV (calling the L1 Pallas kernel) and the
local dot product used by CG. Lowered once to HLO text by ``aot.py``;
never imported at request time.

Functions return 1-tuples to match the HLO-text interchange convention
(``return_tuple=True`` → rust unwraps with ``to_tuple1``, see
/opt/xla-example/gen_hlo.py)."""

import jax.numpy as jnp

from .kernels.spmv import spmv_block_ell


def _pick_row_tile(rows_pad: int) -> int:
    """Largest power-of-two tile ≤ 128 dividing rows_pad."""
    t = 128
    while t > 1 and rows_pad % t:
        t //= 2
    return t


def local_spmv(vals, cols, x):
    """y = A_local @ x_ext via the Pallas Block-ELL kernel."""
    return (spmv_block_ell(vals, cols, x, row_tile=_pick_row_tile(vals.shape[0])),)


def local_dot(a, b):
    """Local partial dot product (global dot = allreduce of these)."""
    return (jnp.sum(a * b),)


def local_axpy(alpha, x, y):
    """y + alpha * x (CG vector update; alpha is a scalar array)."""
    return (y + alpha * x,)
