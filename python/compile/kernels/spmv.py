"""L1 — Block-ELL SpMV as a Pallas kernel.

The paper's SDDE exists to set up sparse matrix-vector products, so the
compute hot-spot of the stack is the local SpMV each rank runs between halo
exchanges. The CPU-cluster workload is re-thought for TPU idiom (DESIGN.md
§Hardware-Adaptation):

* CSR is re-blocked to **Block-ELL**: a dense ``(rows_pad, width)`` pair of
  value / column-index arrays, rows padded to a multiple of the row tile and
  short rows padded with ``(col=0, val=0)``. Static shapes → one XLA
  artifact per shape class.
* The kernel tiles rows with a 1-D grid; each grid step holds one
  ``(row_tile, width)`` tile of vals/cols plus the full x vector in VMEM
  (x is the halo-extended local vector — KiBs, it fits comfortably), and
  computes ``y[i] = Σ_j vals[i,j] · x[cols[i,j]]`` via a VMEM gather and a
  row-sum. On real TPU hardware the gather feeds the VPU; the row-sum
  reduction vectorizes over the 8×128 lanes.
* ``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; numerics are validated through the interpret path and the
  pure-jnp oracle in ``ref.py`` (see /opt/xla-example/README.md).

VMEM footprint per grid step (f32): ``row_tile·width·(4+4) + 4·xlen`` bytes
— for the shipped (1024, 8, 2048) artifact with row_tile=128:
8 KiB tiles + 8 KiB x ≈ 16 KiB, far under the ~16 MiB VMEM budget, leaving
room to scale width or fuse the AXPY. The arithmetic intensity of SpMV is
gather-bound (no MXU use); the roofline estimate lives in EXPERIMENTS.md
§Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(vals_ref, cols_ref, x_ref, o_ref):
    """One row tile: gather x at cols, multiply, reduce over the width."""
    vals = vals_ref[...]          # (row_tile, width) f32
    cols = cols_ref[...]          # (row_tile, width) i32
    x = x_ref[...]                # (xlen,) f32 — whole vector in VMEM
    gathered = x[cols]            # (row_tile, width) gather from VMEM
    o_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def spmv_block_ell(vals, cols, x, *, row_tile=128):
    """Block-ELL SpMV: ``y[i] = sum_j vals[i, j] * x[cols[i, j]]``.

    Args:
      vals: f32[rows_pad, width] — padded entries (0 where absent).
      cols: i32[rows_pad, width] — padded column indices (0 where absent;
        x[0] is multiplied by 0 so any valid index works as padding).
      x:    f32[xlen] — halo-extended local vector.
      row_tile: rows per grid step; must divide rows_pad.

    Returns:
      f32[rows_pad].
    """
    rows_pad, width = vals.shape
    assert cols.shape == (rows_pad, width), (vals.shape, cols.shape)
    assert rows_pad % row_tile == 0, (rows_pad, row_tile)
    (xlen,) = x.shape
    grid = (rows_pad // row_tile,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, width), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, width), lambda i: (i, 0)),
            pl.BlockSpec((xlen,), lambda i: (0,)),  # x replicated per tile
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows_pad,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(vals, cols, x)
