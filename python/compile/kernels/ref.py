"""Pure-jnp oracle for the Block-ELL SpMV kernel (the CORE correctness
signal: pytest asserts the Pallas kernel matches this on random inputs)."""

import jax.numpy as jnp


def spmv_block_ell_ref(vals, cols, x):
    """``y[i] = sum_j vals[i, j] * x[cols[i, j]]`` — no Pallas, no tiling."""
    return jnp.sum(vals * x[cols], axis=1)


def dot_ref(a, b):
    return jnp.sum(a * b)
