"""L2 + AOT pipeline: the model functions produce correct numerics and the
lowering path emits loadable HLO text with a consistent manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import spmv_block_ell_ref


def test_local_spmv_matches_ref():
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.standard_normal((32, 4), dtype=np.float32))
    cols = jnp.asarray(rng.integers(0, 50, size=(32, 4)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((50,), dtype=np.float32))
    (y,) = model.local_spmv(vals, cols, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(spmv_block_ell_ref(vals, cols, x)), rtol=1e-5
    )


def test_local_dot_and_axpy():
    a = jnp.array([1.0, 2.0, 3.0], jnp.float32)
    b = jnp.array([4.0, -5.0, 6.0], jnp.float32)
    (d,) = model.local_dot(a, b)
    assert float(d) == 12.0
    (y,) = model.local_axpy(jnp.float32(2.0), a, b)
    np.testing.assert_allclose(np.asarray(y), [6.0, -1.0, 12.0])


def test_lower_spmv_emits_hlo_text():
    text = aot.lower_spmv(256, 8, 512)
    assert "ENTRY" in text
    assert "f32[256,8]" in text
    # interpret-mode pallas must lower to plain HLO, not a Mosaic call
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_lower_dot_emits_hlo_text():
    text = aot.lower_dot(64)
    assert "ENTRY" in text
    assert "f32[64]" in text


def test_build_all_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    # monkeypatch smaller shape lists for speed
    old_shapes, old_dots = aot.SPMV_SHAPES, aot.DOT_SIZES
    aot.SPMV_SHAPES, aot.DOT_SIZES = [(64, 4, 128)], [32]
    try:
        lines = aot.build_all(out)
    finally:
        aot.SPMV_SHAPES, aot.DOT_SIZES = old_shapes, old_dots
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "spmv 64 4 128 spmv_64x4_x128.hlo.txt" in manifest
    assert "dot 32 dot_32.hlo.txt" in manifest
    for line in lines:
        if line.startswith("#"):
            continue
        fname = line.split()[-1]
        path = os.path.join(out, fname)
        assert os.path.exists(path)
        assert "ENTRY" in open(path).read()


def test_lowered_spmv_executes_like_ref():
    """Round-trip: compile the lowered StableHLO and execute — this is what
    the rust runtime does via PJRT, minus the text hop."""
    rows, width, xlen = 64, 4, 128
    fn = jax.jit(model.local_spmv)
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.standard_normal((rows, width), dtype=np.float32))
    cols = jnp.asarray(rng.integers(0, xlen, size=(rows, width)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((xlen,), dtype=np.float32))
    (got,) = fn(vals, cols, x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(spmv_block_ell_ref(vals, cols, x)), rtol=1e-5
    )
