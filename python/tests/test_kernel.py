"""L1 correctness: the Pallas Block-ELL SpMV kernel vs the pure-jnp oracle
— deterministic cases plus hypothesis sweeps over shapes and values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import spmv_block_ell_ref
from compile.kernels.spmv import spmv_block_ell


def random_ell(rng, rows_pad, width, xlen, row_tile):
    assert rows_pad % row_tile == 0
    vals = rng.standard_normal((rows_pad, width), dtype=np.float32)
    cols = rng.integers(0, xlen, size=(rows_pad, width)).astype(np.int32)
    # pad some entries like the rust converter does: (col 0, val 0)
    mask = rng.random((rows_pad, width)) < 0.3
    vals[mask] = 0.0
    cols[mask] = 0
    x = rng.standard_normal((xlen,), dtype=np.float32)
    return jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x)


def test_identity_rows():
    # A = I (width 1, cols = row index) → y == x[:rows]
    rows, xlen = 16, 16
    vals = jnp.ones((rows, 1), jnp.float32)
    cols = jnp.arange(rows, dtype=jnp.int32).reshape(rows, 1)
    x = jnp.arange(xlen, dtype=jnp.float32) * 2.0
    y = spmv_block_ell(vals, cols, x, row_tile=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=0)


def test_padded_entries_contribute_zero():
    vals = jnp.array([[3.0, 0.0], [0.0, 0.0]], jnp.float32)
    cols = jnp.array([[1, 0], [0, 0]], jnp.int32)
    x = jnp.array([100.0, 2.0], jnp.float32)
    y = spmv_block_ell(vals, cols, x, row_tile=2)
    np.testing.assert_allclose(np.asarray(y), [6.0, 0.0])


def test_matches_dense_matmul():
    rng = np.random.default_rng(0)
    n, width = 64, 8
    vals, cols, x = random_ell(rng, n, width, n, row_tile=16)
    y = spmv_block_ell(vals, cols, x, row_tile=16)
    # densify and compare
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(width):
            dense[i, int(cols[i, j])] += float(vals[i, j])
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(x), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 4),
    row_tile_log=st.integers(2, 5),
    width=st.integers(1, 9),
    xlen=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(tiles, row_tile_log, width, xlen, seed):
    row_tile = 1 << row_tile_log
    rows_pad = tiles * row_tile
    rng = np.random.default_rng(seed)
    vals, cols, x = random_ell(rng, rows_pad, width, xlen, row_tile)
    got = spmv_block_ell(vals, cols, x, row_tile=row_tile)
    want = spmv_block_ell_ref(vals, cols, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_value_ranges(scale, seed):
    rng = np.random.default_rng(seed)
    vals, cols, x = random_ell(rng, 32, 4, 48, row_tile=8)
    vals = vals * scale
    got = spmv_block_ell(vals, cols, x, row_tile=8)
    want = spmv_block_ell_ref(vals, cols, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * scale)


def test_row_tile_invariance():
    rng = np.random.default_rng(3)
    vals, cols, x = random_ell(rng, 64, 6, 100, row_tile=8)
    y8 = spmv_block_ell(vals, cols, x, row_tile=8)
    y16 = spmv_block_ell(vals, cols, x, row_tile=16)
    y64 = spmv_block_ell(vals, cols, x, row_tile=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=1e-6)


def test_bad_row_tile_rejected():
    vals = jnp.zeros((10, 2), jnp.float32)
    cols = jnp.zeros((10, 2), jnp.int32)
    x = jnp.zeros((4,), jnp.float32)
    with pytest.raises(AssertionError):
        spmv_block_ell(vals, cols, x, row_tile=8)


def test_jit_composes():
    # The kernel must lower inside a larger jitted graph (the L2 model).
    @jax.jit
    def step(vals, cols, x):
        y = spmv_block_ell(vals, cols, x, row_tile=8)
        return jnp.sum(y * y)

    rng = np.random.default_rng(5)
    vals, cols, x = random_ell(rng, 16, 3, 20, row_tile=8)
    got = step(vals, cols, x)
    want = jnp.sum(spmv_block_ell_ref(vals, cols, x) ** 2)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
