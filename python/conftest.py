# Ensures `import compile...` resolves when pytest runs from python/.
